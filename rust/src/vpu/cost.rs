//! Cycle-cost models for the four benchmarks, on SHAVEs and on the LEON
//! baseline.
//!
//! # Calibration methodology (DESIGN.md §4–5)
//!
//! We cannot run the vendor toolchain, so per-element cycle counts are
//! *calibrated once* against the paper's own measurements and then used
//! predictively for every other workload shape the benches sweep:
//!
//! * SHAVE aggregate cycles/element are fixed by Table II's VPU-processing
//!   column (binning 3 ms, conv 8/29/114 ms for K=3/7/13, render 164 ms,
//!   CNN 658 ms) at 12 SHAVEs x 600 MHz.
//! * Conv sizes the paper does not report (K=5/9/11) interpolate the
//!   quadratic-in-K fit through the three measured points.
//! * LEON scalar factors are fixed by the paper's reported speedups
//!   (binning 14x, conv up to 75x, render 10–16x content-dependent, CNN
//!   projected >100x because LEON lacks 16-bit FP and runs the fp32
//!   model).
//!
//! The render model is *content-dependent by construction*: its cost is a
//! function of the actual projected triangle bounding boxes per band, so
//! different poses/meshes reproduce the paper's 10–16x speedup spread.

use crate::config::VpuConfig;
use crate::fabric::clock::SimTime;

/// Benchmark identity (paper §III-C).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BenchKind {
    /// 2x2 stride-2 averaging binning.
    Binning,
    /// K x K floating-point convolution.
    Conv { k: usize },
    /// Triangle-mesh depth rendering.
    Render,
    /// 6-layer CNN ship detection (per 128x128 patch).
    Cnn,
    /// CCSDS-123 lossless hyperspectral compression (band-parallel).
    Ccsds,
}

/// Workload shape parameters the cost model needs.
#[derive(Clone, Debug, Default)]
pub struct Workload {
    /// Output elements (pixels / logits).
    pub out_elems: usize,
    /// Input elements (pixels, all channels).
    pub in_elems: usize,
    /// Render only: per-band rasterization effort — for each band, the
    /// total bbox pixel tests Σ_tri bbox_rows_in_band x bbox_width.
    pub band_bbox_px: Vec<u64>,
    /// Render only: triangle count (per-band setup cost).
    pub n_tris: usize,
    /// CNN only: number of 128x128 patches.
    pub patches: usize,
    /// CNN only: arithmetic precision of the inference path (ISSUE 10).
    /// `Precision::Int8` halves the per-MAC SHAVE cost
    /// ([`SHAVE_CP_MAC_INT8`]); every other benchmark ignores it.
    pub precision: crate::Precision,
}

// ---------------------------------------------------------------------------
// SHAVE aggregate cycles/element (12-core lane-cycle totals; see module doc)
// ---------------------------------------------------------------------------

/// Binning: 3 ms for 1 MPixel output => 3e-3 * 12 * 600e6 / 2^20.
/// (DRAM-bandwidth-bound: ~4 input bytes + 1 output byte per element.)
pub const SHAVE_CPE_BINNING: f64 = 20.6;

/// Conv cycles/output-pixel as a function of K: quadratic fit through the
/// measured K=3 (8 ms -> 54.9), K=7 (29 ms -> 199.1), K=13 (114 ms ->
/// 782.5) points: cpe(K) = 75.2 - 25.11 K + 6.114 K^2.
pub fn shave_cpe_conv(k: usize) -> f64 {
    let kf = k as f64;
    75.2 - 25.11 * kf + 6.114 * kf * kf
}

/// Render: cycles per bbox pixel test (barycentric + z-compare, SIMD) and
/// per-triangle-per-band setup. Calibrated so the reference mesh/pose
/// (320-face asteroid at ~3 model radii: ~3.1 MPixel of bbox tests on
/// 1024^2) lands at ~164 ms.
pub const SHAVE_CP_BBOX_TEST: f64 = 375.0;
pub const SHAVE_CP_TRI_SETUP: f64 = 110.0;

/// CNN: aggregate cycles per MAC (fp16 SIMD). The 64-patch dynamic
/// schedule puts ceil(64/12)=6 patches on the busiest SHAVE (a 12.5 %
/// imbalance over ideal), so the per-MAC cost is calibrated such that
/// the *scheduled makespan* — not the ideal parallel time — reproduces
/// Table II's 658 ms: 658 ms * (64/6 patches) / 985.7 MMAC * 600 MHz.
pub const SHAVE_CP_MAC: f64 = 4.276;

/// CNN int8 (ISSUE 10): the SHAVEs' 128-bit SIMD lanes hold twice as
/// many int8 MACs as fp16 ones, so the quantized path is modelled at
/// half the fp16 per-MAC cost (the per-layer requantize folds into the
/// MAC pipeline's store stage). An engineering estimate in the same
/// calibrated lane-cycle currency — the paper runs the CNN in fp16
/// only — kept exactly `SHAVE_CP_MAC / 2` so the modelled int8 speedup
/// is a clean 2x over the Table II baseline.
pub const SHAVE_CP_MAC_INT8: f64 = SHAVE_CP_MAC / 2.0;

/// CCSDS-123: aggregate cycles per *input* sample (predict + map +
/// Golomb-Rice emit, all-integer). Not a Table II row — the paper runs
/// CCSDS-123 on the FPGA (Table I) — so this is an engineering estimate
/// in the same 12-SHAVE lane-cycle currency as the calibrated kernels.
pub const SHAVE_CPE_CCSDS: f64 = 26.0;

/// MACs of one 128x128x3 patch through the 6-layer network.
pub fn cnn_macs_per_patch() -> u64 {
    let conv = |hw: u64, cin: u64, cout: u64| hw * hw * 9 * cin * cout;
    conv(128, 3, 8) + conv(64, 8, 16) + conv(32, 16, 32) + conv(16, 32, 32)
        + 2048 * 57
        + 57 * 2
}

// ---------------------------------------------------------------------------
// LEON scalar factors (single core @230 MHz; see module doc)
// ---------------------------------------------------------------------------

/// t_leon = total_shave_cycles * sigma / f_leon. sigma < 1 means the
/// scalar per-element cycle count is below the SHAVE lane-cycle aggregate
/// (true for memory-bound kernels where SHAVEs stall on DRAM too).
pub fn leon_sigma(kind: BenchKind) -> f64 {
    match kind {
        // 14x speedup: "mainly comes from the parallelization to 12 cores
        // (LEON has to scan the entire 4MP image)".
        BenchKind::Binning => 0.447,
        // Speedup grows with arithmetic intensity up to 75x at K=13
        // ("up to 75x ... due to increased computational complexity").
        BenchKind::Conv { k } => {
            // Fit through 35x @K=3 and 75x @K=13 (linear in K).
            let speedup = 35.0 + (k as f64 - 3.0) * 4.0;
            speedup / AGG_FACTOR
        }
        // 10-16x content-dependent; sigma fixed, spread comes from the
        // band-level content entering the cost formula.
        BenchKind::Render => 0.415,
        // Projected "more than 2 orders of magnitude": LEON runs fp32
        // (no fp16 support) scalar code.
        BenchKind::Cnn => 4.79,
        // All-integer and branchy: modest vectorization benefit, gain
        // mostly from the 12-way band fan-out (~19x).
        BenchKind::Ccsds => 0.6,
    }
}

/// speedup = sigma * (12 * 600 MHz / 230 MHz) = sigma * 31.3.
pub const AGG_FACTOR: f64 = 12.0 * 600.0 / 230.0;

// ---------------------------------------------------------------------------
// The model
// ---------------------------------------------------------------------------

/// Per-benchmark timing model over a [`VpuConfig`].
///
/// The `SHAVE_CPE_*` constants above are *lane-cycle aggregates*
/// calibrated at the paper's 12 SHAVEs x 600 MHz; they are properties
/// of the kernels, not of a particular part, so a heterogeneous fleet
/// (ISSUE 8) reuses them per node: [`CostModel::shave_time_ideal`]
/// divides the same aggregate by *this node's* `n_shaves x clock`, and
/// a `1x300MHz:4` node honestly prices 6x slower than the paper part.
#[derive(Clone, Debug)]
pub struct CostModel {
    pub vpu: VpuConfig,
}

impl CostModel {
    pub fn new(vpu: VpuConfig) -> CostModel {
        CostModel { vpu }
    }

    /// Total SHAVE lane-cycles for the workload (before scheduling).
    /// The CNN arm prices at the workload's precision
    /// ([`SHAVE_CP_MAC`] fp16 / [`SHAVE_CP_MAC_INT8`] quantized).
    pub fn shave_total_cycles(&self, kind: BenchKind, w: &Workload) -> f64 {
        match kind {
            BenchKind::Binning => SHAVE_CPE_BINNING * w.out_elems as f64,
            BenchKind::Conv { k } => shave_cpe_conv(k) * w.out_elems as f64,
            BenchKind::Render => {
                let bbox: u64 = w.band_bbox_px.iter().sum();
                SHAVE_CP_BBOX_TEST * bbox as f64
                    + SHAVE_CP_TRI_SETUP
                        * (w.n_tris * w.band_bbox_px.len().max(1)) as f64
            }
            BenchKind::Cnn => {
                let cp_mac = match w.precision {
                    crate::Precision::F32 => SHAVE_CP_MAC,
                    crate::Precision::Int8 => SHAVE_CP_MAC_INT8,
                };
                cp_mac * (cnn_macs_per_patch() * w.patches as u64) as f64
            }
            // Cost tracks input samples: every sample is predicted and
            // coded exactly once regardless of the output bit budget.
            BenchKind::Ccsds => SHAVE_CPE_CCSDS * w.in_elems as f64,
        }
    }

    /// Per-band cycle costs for the scheduler (uniform split except
    /// render, which uses real per-band content).
    pub fn band_cycles(&self, kind: BenchKind, w: &Workload, n_bands: usize) -> Vec<f64> {
        match kind {
            BenchKind::Render => {
                let setup = SHAVE_CP_TRI_SETUP * w.n_tris as f64;
                w.band_bbox_px
                    .iter()
                    .map(|&b| SHAVE_CP_BBOX_TEST * b as f64 + setup)
                    .collect()
            }
            _ => {
                let total = self.shave_total_cycles(kind, w);
                vec![total / n_bands as f64; n_bands]
            }
        }
    }

    /// Ideal (perfect-parallel) SHAVE processing time.
    pub fn shave_time_ideal(&self, kind: BenchKind, w: &Workload) -> SimTime {
        let cycles = self.shave_total_cycles(kind, w);
        SimTime::from_secs(
            cycles / (self.vpu.n_shaves as f64 * self.vpu.shave_clock_hz),
        )
    }

    /// LEON single-core baseline time. Always priced at the fp32
    /// cycle base whatever the workload's precision: the LEON scalar
    /// core has no int8 SIMD to exploit (it runs the fp32 model), so
    /// the baseline does not speed up when the SHAVEs quantize.
    pub fn leon_time(&self, kind: BenchKind, w: &Workload) -> SimTime {
        let base = match (kind, w.precision) {
            (BenchKind::Cnn, crate::Precision::Int8) => {
                let f32_w = Workload {
                    precision: crate::Precision::F32,
                    ..w.clone()
                };
                self.shave_total_cycles(kind, &f32_w)
            }
            _ => self.shave_total_cycles(kind, w),
        };
        SimTime::from_secs(base * leon_sigma(kind) / self.vpu.leon_clock_hz)
    }

    /// Speedup of the ideal SHAVE implementation over LEON.
    pub fn speedup(&self, kind: BenchKind, w: &Workload) -> f64 {
        self.leon_time(kind, w).as_secs()
            / self.shave_time_ideal(kind, w).as_secs()
    }

    /// One full ECC scrub pass over a DRAM region (ISSUE 9
    /// `recovery::Strategy::Scrub`): the scrubber streams
    /// `region_bytes` through the DMA engine, so the pass is priced at
    /// this node's DMA rate (read + SEC-DED check + write-back folded
    /// into the streaming rate, as on real scrub engines).
    pub fn scrub_pass_time(&self, region_bytes: usize) -> SimTime {
        SimTime::from_secs(region_bytes as f64 / self.vpu.dma_bytes_per_s)
    }

    /// Amortized per-frame cost of scrubbing once every `period`
    /// frames. Period 0 means "never" and costs nothing.
    pub fn scrub_overhead(&self, region_bytes: usize, period: u32) -> SimTime {
        if period == 0 {
            return SimTime::from_secs(0.0);
        }
        SimTime::from_secs(
            self.scrub_pass_time(region_bytes).as_secs() / period as f64,
        )
    }
}

/// Standard Table II workloads.
pub mod workloads {
    use super::Workload;

    /// Binning: 2048x2048 8bpp in, 1024x1024 out.
    pub fn binning_4mp() -> Workload {
        Workload {
            out_elems: 1024 * 1024,
            in_elems: 2048 * 2048,
            ..Default::default()
        }
    }

    /// Conv: 1024x1024 in/out.
    pub fn conv_1mp() -> Workload {
        Workload {
            out_elems: 1024 * 1024,
            in_elems: 1024 * 1024,
            ..Default::default()
        }
    }

    /// CNN: 1 MPixel RGB frame = 64 patches.
    pub fn cnn_1mp() -> Workload {
        Workload {
            out_elems: 64 * 2,
            in_elems: 1024 * 1024 * 3,
            patches: 64,
            ..Default::default()
        }
    }

    /// CCSDS-123: 8-band 256x256 16-bit cube in, 64-word digest out.
    pub fn ccsds_8band() -> Workload {
        Workload {
            out_elems: 64,
            in_elems: 8 * 256 * 256,
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VpuConfig;

    fn model() -> CostModel {
        CostModel::new(VpuConfig::myriad2())
    }

    #[test]
    fn binning_matches_table_ii_3ms() {
        let t = model().shave_time_ideal(BenchKind::Binning, &workloads::binning_4mp());
        assert!((t.as_ms() - 3.0).abs() < 0.1, "{} ms", t.as_ms());
    }

    #[test]
    fn conv_matches_table_ii_all_measured_k() {
        let m = model();
        let w = workloads::conv_1mp();
        for (k, expect_ms) in [(3, 8.0), (7, 29.0), (13, 114.0)] {
            let t = m.shave_time_ideal(BenchKind::Conv { k }, &w);
            assert!(
                (t.as_ms() - expect_ms).abs() / expect_ms < 0.03,
                "K={k}: {} ms vs {expect_ms}",
                t.as_ms()
            );
        }
    }

    #[test]
    fn conv_interpolated_k_monotonic() {
        let m = model();
        let w = workloads::conv_1mp();
        let mut last = 0.0;
        for k in [3, 5, 7, 9, 11, 13] {
            let t = m.shave_time_ideal(BenchKind::Conv { k }, &w).as_ms();
            assert!(t > last, "K={k} {t} !> {last}");
            last = t;
        }
    }

    #[test]
    fn cnn_matches_table_ii_658ms_scheduled() {
        // The Table II figure is the *scheduled* makespan: 64 patches on
        // 12 SHAVEs puts 6 on the busiest core (12.5% over ideal).
        let m = model();
        let w = workloads::cnn_1mp();
        let bands = m.band_cycles(BenchKind::Cnn, &w, 64);
        let t = crate::vpu::scheduler::dynamic_makespan(&bands, 12, 600.0e6);
        assert!((t.as_ms() - 658.0).abs() / 658.0 < 0.03, "{} ms", t.as_ms());
        // Ideal parallel time is correspondingly lower.
        let ideal = m.shave_time_ideal(BenchKind::Cnn, &w);
        assert!(ideal < t);
    }

    #[test]
    fn cnn_int8_halves_shave_cycles_and_keeps_leon_baseline() {
        let m = model();
        let w = workloads::cnn_1mp();
        let w8 = Workload {
            precision: crate::Precision::Int8,
            ..w.clone()
        };
        let c32 = m.shave_total_cycles(BenchKind::Cnn, &w);
        let c8 = m.shave_total_cycles(BenchKind::Cnn, &w8);
        assert!((c8 * 2.0 - c32).abs() < 1e-3, "{c8} vs {c32}");
        // LEON runs the fp32 model either way, so quantizing the
        // SHAVEs widens the speedup instead of shrinking the baseline.
        assert_eq!(
            m.leon_time(BenchKind::Cnn, &w),
            m.leon_time(BenchKind::Cnn, &w8)
        );
        let (s32, s8) = (
            m.speedup(BenchKind::Cnn, &w),
            m.speedup(BenchKind::Cnn, &w8),
        );
        assert!((s8 - 2.0 * s32).abs() / s32 < 1e-3, "{s8} vs {s32}");
        // Non-CNN kinds ignore the precision knob entirely.
        let conv8 = Workload {
            precision: crate::Precision::Int8,
            ..workloads::conv_1mp()
        };
        assert_eq!(
            m.shave_total_cycles(BenchKind::Conv { k: 3 }, &conv8),
            m.shave_total_cycles(BenchKind::Conv { k: 3 }, &workloads::conv_1mp())
        );
    }

    #[test]
    fn cnn_macs_magnitude() {
        let m = cnn_macs_per_patch();
        assert!(
            (15_000_000..16_000_000).contains(&m),
            "{m} MACs/patch"
        );
    }

    #[test]
    fn binning_speedup_is_papers_14x() {
        let s = model().speedup(BenchKind::Binning, &workloads::binning_4mp());
        assert!((s - 14.0).abs() < 0.5, "speedup {s}");
    }

    #[test]
    fn conv_speedup_up_to_75x() {
        let m = model();
        let w = workloads::conv_1mp();
        let s3 = m.speedup(BenchKind::Conv { k: 3 }, &w);
        let s13 = m.speedup(BenchKind::Conv { k: 13 }, &w);
        assert!((s3 - 35.0).abs() < 2.0, "s3 {s3}");
        assert!((s13 - 75.0).abs() < 2.0, "s13 {s13}");
        assert!(s3 < s13);
    }

    #[test]
    fn cnn_speedup_over_two_orders() {
        let s = model().speedup(BenchKind::Cnn, &workloads::cnn_1mp());
        assert!(s > 100.0, "speedup {s}");
    }

    #[test]
    fn render_cost_depends_on_content() {
        let m = model();
        let sparse = Workload {
            out_elems: 1 << 20,
            band_bbox_px: vec![10_000; 32],
            n_tris: 320,
            ..Default::default()
        };
        let dense = Workload {
            out_elems: 1 << 20,
            band_bbox_px: vec![60_000; 32],
            n_tris: 320,
            ..Default::default()
        };
        let ts = m.shave_time_ideal(BenchKind::Render, &sparse);
        let td = m.shave_time_ideal(BenchKind::Render, &dense);
        assert!(td.as_secs() > 3.0 * ts.as_secs());
    }

    #[test]
    fn ccsds_cost_is_sane() {
        let m = model();
        let w = workloads::ccsds_8band();
        let t = m.shave_time_ideal(BenchKind::Ccsds, &w);
        // 26 cycles x 512K samples over 12 SHAVEs @600 MHz ~ 1.9 ms.
        assert!((1.0..4.0).contains(&t.as_ms()), "{} ms", t.as_ms());
        let s = m.speedup(BenchKind::Ccsds, &w);
        assert!((15.0..25.0).contains(&s), "speedup {s}");
        // Uniform per-band split (the `_` arm): 8 equal bands.
        let bands = m.band_cycles(BenchKind::Ccsds, &w, 8);
        assert_eq!(bands.len(), 8);
        assert!((bands[0] - bands[7]).abs() < 1e-9);
    }

    #[test]
    fn scrub_overhead_amortizes_a_dma_priced_pass() {
        let m = model();
        // 24 MB frame-buffer region at 1.5 GB/s DMA: one pass = 16 ms.
        let region = 24 * 1024 * 1024;
        let pass = m.scrub_pass_time(region);
        assert!((pass.as_ms() - 16.78).abs() < 0.1, "{} ms", pass.as_ms());
        let per_frame = m.scrub_overhead(region, 8);
        assert!(
            (per_frame.as_secs() - pass.as_secs() / 8.0).abs() < 1e-12,
            "period divides the pass"
        );
        assert_eq!(m.scrub_overhead(region, 0).as_secs(), 0.0, "period 0 = never");
    }

    #[test]
    fn leon_time_scales_with_sigma() {
        let m = model();
        let w = workloads::binning_4mp();
        let leon = m.leon_time(BenchKind::Binning, &w);
        // LEON binning ~42 ms (3 ms x 14).
        assert!((leon.as_ms() - 42.0).abs() < 2.0, "{} ms", leon.as_ms());
    }
}
