//! VPU-side driver shims: the CamGeneric (CIF Rx) and LCD (Tx) software
//! stacks of paper §III-B, at transaction level.
//!
//! `CamInit()/CamStart()/CamStop()` and `LCDInit()/LCDQueueFrame()/...`
//! become: receive a wire frame into a DRAM buffer (checking CRC), and
//! queue a DRAM buffer out as a wire frame. Each call carries the LEON
//! driver overhead the paper's firmware pays at frame boundaries.
//!
//! On a heterogeneous fleet (ISSUE 8) each node still clocks its
//! CIF/LCD links off the *host-side* pixel PLL — wire rates are a
//! property of the framing processor, not of the attached VPU's grade —
//! so `for_node` takes the shared iface clock while the per-node
//! compute/copy rates live in the node's own `CostModel`. What a
//! heterogeneous fleet *does* change on the wire is arbitration: the
//! shared host bus (`fabric::bus::HostBus`) queues concurrent CIF/LCD
//! grants, surfacing as per-frame `bus_wait` in the stream's timing.

use crate::error::Result;
use crate::fabric::clock::{ClockDomain, SimTime};
use crate::iface::signals::WireFrame;
use crate::iface::timing;
use crate::util::image::Frame;

/// Outcome of one CamGeneric reception: the reassembled DRAM frame plus
/// the CRC verdict. This is the unified report-and-recover CRC policy
/// (ISSUE 4): like the FPGA LCD module, the driver hands software
/// whatever arrived and *flags* it — drop/accept/retransmit decisions
/// belong to the coordinator, not the Rx path.
#[derive(Clone, Debug)]
pub struct CamRx {
    pub frame: Frame,
    pub done_at: SimTime,
    pub crc_ok: bool,
    /// CRC recomputed over the received payload.
    pub computed: u16,
    /// CRC carried by the wire frame's CRC line.
    pub received: u16,
}

/// LEON-side driver overhead per frame (interrupt handling, descriptor
/// setup) — microseconds, negligible against 21 ms transfers but modelled
/// for completeness.
pub const DRIVER_OVERHEAD: SimTime = SimTime(40_000_000); // 40 us

/// VPU CIF receive path (CamGeneric).
#[derive(Clone, Debug)]
pub struct CamGeneric {
    pub clock: ClockDomain,
    pub porch: usize,
    /// Topology index of the VPU node this driver instance runs on
    /// (ISSUE 5). The coordinator derives the fault plan's
    /// `Hop::Cif(node)` id from it — the frame draws its hop from the
    /// hardware it passes through — and `frames_received`/`crc_errors`
    /// are per-node by construction.
    pub node: usize,
    pub frames_received: u64,
    pub crc_errors: u64,
    /// Frames whose wire lines faulted but whose payload the FEC
    /// sidecar reconstructed before receive (ISSUE 9
    /// `recovery::Strategy::Fec`) — repaired frames pass CRC at Rx, so
    /// they do *not* count in `crc_errors` and cost no retransmit.
    pub fec_corrected: u64,
}

impl CamGeneric {
    pub fn new(pixel_clock_hz: f64, porch: usize) -> CamGeneric {
        CamGeneric::for_node(0, pixel_clock_hz, porch)
    }

    /// [`CamGeneric::new`] for a specific VPU node of the topology.
    pub fn for_node(node: usize, pixel_clock_hz: f64, porch: usize) -> CamGeneric {
        CamGeneric {
            clock: ClockDomain::new(pixel_clock_hz),
            porch,
            node,
            frames_received: 0,
            crc_errors: 0,
            fec_corrected: 0,
        }
    }

    /// Record an FEC erasure recovery on this node's CIF Rx path.
    pub fn note_corrected(&mut self) {
        self.fec_corrected += 1;
    }

    /// CIF Rx: wire -> DRAM frame. Always yields the frame (whatever
    /// arrived — the DMA descriptor filled the DRAM buffer regardless)
    /// with the CRC verdict flagged in the returned [`CamRx`]; `Err`
    /// only for geometry violations. Earlier revisions hard-errored on
    /// a CRC mismatch while the LCD side tolerated-and-reported; the
    /// policy is now report-and-recover on both ends.
    pub fn receive(&mut self, wire: &WireFrame, now: SimTime) -> Result<CamRx> {
        let t = timing::frame_time(&self.clock, wire.width, wire.height, self.porch);
        let (frame, check) = wire.to_frame_reported()?;
        self.note(check.ok());
        Ok(CamRx {
            frame,
            done_at: now + t + DRIVER_OVERHEAD,
            crc_ok: check.ok(),
            computed: check.computed,
            received: check.received,
        })
    }

    /// [`CamGeneric::receive`] consuming the wire frame: the payload
    /// **moves** into the returned DRAM frame instead of being cloned —
    /// the DMA-descriptor handoff of the real CamGeneric driver, and the
    /// zero-copy path of the streaming coordinator.
    pub fn receive_owned(&mut self, wire: WireFrame, now: SimTime) -> Result<CamRx> {
        let t = timing::frame_time(&self.clock, wire.width, wire.height, self.porch);
        let (frame, check) = wire.into_frame_reported()?;
        self.note(check.ok());
        Ok(CamRx {
            frame,
            done_at: now + t + DRIVER_OVERHEAD,
            crc_ok: check.ok(),
            computed: check.computed,
            received: check.received,
        })
    }

    fn note(&mut self, crc_ok: bool) {
        self.frames_received += 1;
        if !crc_ok {
            self.crc_errors += 1;
        }
    }
}

/// VPU LCD transmit path.
#[derive(Clone, Debug)]
pub struct LcdDriver {
    pub clock: ClockDomain,
    pub porch: usize,
    /// Topology index of the VPU node this driver instance runs on —
    /// the source of the fault plan's `Hop::Lcd(node)` id and of
    /// `FrameRun::node` attribution.
    pub node: usize,
    pub frames_sent: u64,
}

impl LcdDriver {
    pub fn new(pixel_clock_hz: f64, porch: usize) -> LcdDriver {
        LcdDriver::for_node(0, pixel_clock_hz, porch)
    }

    /// [`LcdDriver::new`] for a specific VPU node of the topology.
    pub fn for_node(node: usize, pixel_clock_hz: f64, porch: usize) -> LcdDriver {
        LcdDriver {
            clock: ClockDomain::new(pixel_clock_hz),
            porch,
            node,
            frames_sent: 0,
        }
    }

    /// LCDQueueFrame + LCDStartOneShot: DRAM frame -> wire.
    pub fn send(&mut self, frame: &Frame, now: SimTime) -> (WireFrame, SimTime) {
        let wire = WireFrame::from_frame(frame);
        let t = timing::frame_time(&self.clock, frame.width, frame.height, self.porch);
        self.frames_sent += 1;
        (wire, now + t + DRIVER_OVERHEAD)
    }

    /// [`LcdDriver::send`] consuming the frame: the DRAM payload
    /// **moves** onto the wire (LCDQueueFrame queues the buffer, it
    /// does not copy it) — the zero-copy egress path.
    pub fn send_owned(&mut self, frame: Frame, now: SimTime) -> (WireFrame, SimTime) {
        let t = timing::frame_time(&self.clock, frame.width, frame.height, self.porch);
        let wire = WireFrame::from_frame_owned(frame);
        self.frames_sent += 1;
        (wire, now + t + DRIVER_OVERHEAD)
    }

    /// [`LcdDriver::send`] copying the payload into a recycled buffer —
    /// the retransmission path: the DRAM frame must survive the send so
    /// a CRC-failed transfer can be re-queued, but the wire copy still
    /// comes from the arena instead of a fresh allocation.
    pub fn send_with(
        &mut self,
        frame: &Frame,
        now: SimTime,
        payload: Vec<u32>,
    ) -> (WireFrame, SimTime) {
        let t = timing::frame_time(&self.clock, frame.width, frame.height, self.porch);
        let wire = WireFrame::from_frame_with(frame, payload);
        self.frames_sent += 1;
        (wire, now + t + DRIVER_OVERHEAD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::image::PixelFormat;
    use crate::util::rng::Rng;

    fn frame(w: usize, h: usize, seed: u64) -> Frame {
        let mut rng = Rng::new(seed);
        Frame::from_data(
            w,
            h,
            PixelFormat::Bpp16,
            (0..w * h).map(|_| rng.next_u32() & 0xFFFF).collect(),
        )
        .unwrap()
    }

    #[test]
    fn receive_then_send_roundtrip() {
        let f = frame(64, 64, 1);
        let wire = WireFrame::from_frame(&f);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let rx = cam.receive(&wire, SimTime::ZERO).unwrap();
        assert_eq!(rx.frame, f);
        assert!(rx.crc_ok);
        let mut lcd = LcdDriver::new(50.0e6, 27);
        let (wire2, t2) = lcd.send(&rx.frame, rx.done_at);
        assert!(wire2.to_frame().is_ok());
        assert!(t2 > rx.done_at);
        assert_eq!(cam.frames_received, 1);
        assert_eq!(lcd.frames_sent, 1);
    }

    #[test]
    fn owned_roundtrip_matches_borrowing_roundtrip() {
        let f = frame(64, 64, 7);
        let wire = WireFrame::from_frame(&f);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let rx_ref = cam.receive(&wire, SimTime::ZERO).unwrap();
        let rx_own = cam.receive_owned(wire, SimTime::ZERO).unwrap();
        assert_eq!(rx_ref.frame, rx_own.frame);
        assert_eq!(rx_ref.done_at, rx_own.done_at);
        assert_eq!(cam.frames_received, 2);
        let mut lcd = LcdDriver::new(50.0e6, 27);
        let (w_ref, _) = lcd.send(&rx_ref.frame, SimTime::ZERO);
        let (w_own, _) = lcd.send_owned(rx_own.frame, SimTime::ZERO);
        assert_eq!(w_ref, w_own);
        assert_eq!(lcd.frames_sent, 2);
    }

    #[test]
    fn send_with_recycled_buffer_matches_send() {
        let f = frame(48, 16, 11);
        let mut lcd = LcdDriver::new(50.0e6, 27);
        let (w_ref, t_ref) = lcd.send(&f, SimTime::ZERO);
        let (w_buf, t_buf) = lcd.send_with(&f, SimTime::ZERO, vec![7u32; 4096]);
        assert_eq!(w_ref, w_buf);
        assert_eq!(t_ref, t_buf);
        assert_eq!(lcd.frames_sent, 2);
    }

    #[test]
    fn corrupted_wire_flagged_not_rejected_owned() {
        // Unified report-and-recover policy (ISSUE 4): the corrupt
        // frame is still delivered, flagged, and counted.
        let f = frame(32, 32, 9);
        let mut wire = WireFrame::from_frame(&f);
        wire.corrupt_bit(5, 1);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let rx = cam.receive_owned(wire, SimTime::ZERO).unwrap();
        assert!(!rx.crc_ok);
        assert_ne!(rx.computed, rx.received);
        assert_ne!(rx.frame, f, "what arrived, not what was sent");
        assert_eq!(cam.crc_errors, 1);
        assert_eq!(cam.frames_received, 1);
    }

    #[test]
    fn corrupted_wire_flagged_not_rejected() {
        let f = frame(32, 32, 2);
        let mut wire = WireFrame::from_frame(&f);
        wire.corrupt_bit(5, 1);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let rx = cam.receive(&wire, SimTime::ZERO).unwrap();
        assert!(!rx.crc_ok);
        assert_eq!(cam.crc_errors, 1);
        assert_eq!(cam.frames_received, 1);
    }

    #[test]
    fn fec_corrections_count_separately_from_crc_errors() {
        let mut cam = CamGeneric::new(50.0e6, 27);
        assert_eq!(cam.fec_corrected, 0);
        cam.note_corrected();
        cam.note_corrected();
        assert_eq!(cam.fec_corrected, 2);
        assert_eq!(cam.crc_errors, 0, "corrections are not wire errors");
    }

    #[test]
    fn node_tags_default_zero_and_stick() {
        let cam = CamGeneric::new(50.0e6, 27);
        assert_eq!(cam.node, 0);
        let cam3 = CamGeneric::for_node(3, 50.0e6, 27);
        assert_eq!(cam3.node, 3);
        assert_eq!(cam3.clock.freq_hz, cam.clock.freq_hz);
        let lcd = LcdDriver::for_node(2, 50.0e6, 27);
        assert_eq!(lcd.node, 2);
        assert_eq!(LcdDriver::new(50.0e6, 27).node, 0);
    }

    #[test]
    fn rx_time_matches_wire_rate() {
        let f = frame(1024, 1024, 3);
        let wire = WireFrame::from_frame(&f);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let rx = cam.receive(&wire, SimTime::ZERO).unwrap();
        assert!((rx.done_at.as_ms() - 21.6).abs() < 0.2, "{} ms", rx.done_at.as_ms());
    }
}
