//! VPU-side driver shims: the CamGeneric (CIF Rx) and LCD (Tx) software
//! stacks of paper §III-B, at transaction level.
//!
//! `CamInit()/CamStart()/CamStop()` and `LCDInit()/LCDQueueFrame()/...`
//! become: receive a wire frame into a DRAM buffer (checking CRC), and
//! queue a DRAM buffer out as a wire frame. Each call carries the LEON
//! driver overhead the paper's firmware pays at frame boundaries.

use crate::error::Result;
use crate::fabric::clock::{ClockDomain, SimTime};
use crate::iface::signals::WireFrame;
use crate::iface::timing;
use crate::util::image::Frame;

/// LEON-side driver overhead per frame (interrupt handling, descriptor
/// setup) — microseconds, negligible against 21 ms transfers but modelled
/// for completeness.
pub const DRIVER_OVERHEAD: SimTime = SimTime(40_000_000); // 40 us

/// VPU CIF receive path (CamGeneric).
#[derive(Clone, Debug)]
pub struct CamGeneric {
    pub clock: ClockDomain,
    pub porch: usize,
    pub frames_received: u64,
    pub crc_errors: u64,
}

impl CamGeneric {
    pub fn new(pixel_clock_hz: f64, porch: usize) -> CamGeneric {
        CamGeneric {
            clock: ClockDomain::new(pixel_clock_hz),
            porch,
            frames_received: 0,
            crc_errors: 0,
        }
    }

    /// CIF Rx: wire -> DRAM frame. Returns the frame and completion time.
    pub fn receive(&mut self, wire: &WireFrame, now: SimTime) -> Result<(Frame, SimTime)> {
        let t = timing::frame_time(&self.clock, wire.width, wire.height, self.porch);
        let frame = match wire.to_frame() {
            Ok(f) => f,
            Err(e) => {
                self.crc_errors += 1;
                return Err(e);
            }
        };
        self.frames_received += 1;
        Ok((frame, now + t + DRIVER_OVERHEAD))
    }

    /// [`CamGeneric::receive`] consuming the wire frame: the payload
    /// **moves** into the returned DRAM frame instead of being cloned —
    /// the DMA-descriptor handoff of the real CamGeneric driver, and the
    /// zero-copy path of the streaming coordinator.
    pub fn receive_owned(&mut self, wire: WireFrame, now: SimTime) -> Result<(Frame, SimTime)> {
        let t = timing::frame_time(&self.clock, wire.width, wire.height, self.porch);
        let frame = match wire.into_frame() {
            Ok(f) => f,
            Err(e) => {
                self.crc_errors += 1;
                return Err(e);
            }
        };
        self.frames_received += 1;
        Ok((frame, now + t + DRIVER_OVERHEAD))
    }
}

/// VPU LCD transmit path.
#[derive(Clone, Debug)]
pub struct LcdDriver {
    pub clock: ClockDomain,
    pub porch: usize,
    pub frames_sent: u64,
}

impl LcdDriver {
    pub fn new(pixel_clock_hz: f64, porch: usize) -> LcdDriver {
        LcdDriver {
            clock: ClockDomain::new(pixel_clock_hz),
            porch,
            frames_sent: 0,
        }
    }

    /// LCDQueueFrame + LCDStartOneShot: DRAM frame -> wire.
    pub fn send(&mut self, frame: &Frame, now: SimTime) -> (WireFrame, SimTime) {
        let wire = WireFrame::from_frame(frame);
        let t = timing::frame_time(&self.clock, frame.width, frame.height, self.porch);
        self.frames_sent += 1;
        (wire, now + t + DRIVER_OVERHEAD)
    }

    /// [`LcdDriver::send`] consuming the frame: the DRAM payload
    /// **moves** onto the wire (LCDQueueFrame queues the buffer, it
    /// does not copy it) — the zero-copy egress path.
    pub fn send_owned(&mut self, frame: Frame, now: SimTime) -> (WireFrame, SimTime) {
        let t = timing::frame_time(&self.clock, frame.width, frame.height, self.porch);
        let wire = WireFrame::from_frame_owned(frame);
        self.frames_sent += 1;
        (wire, now + t + DRIVER_OVERHEAD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::image::PixelFormat;
    use crate::util::rng::Rng;

    fn frame(w: usize, h: usize, seed: u64) -> Frame {
        let mut rng = Rng::new(seed);
        Frame::from_data(
            w,
            h,
            PixelFormat::Bpp16,
            (0..w * h).map(|_| rng.next_u32() & 0xFFFF).collect(),
        )
        .unwrap()
    }

    #[test]
    fn receive_then_send_roundtrip() {
        let f = frame(64, 64, 1);
        let wire = WireFrame::from_frame(&f);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let (rx, t1) = cam.receive(&wire, SimTime::ZERO).unwrap();
        assert_eq!(rx, f);
        let mut lcd = LcdDriver::new(50.0e6, 27);
        let (wire2, t2) = lcd.send(&rx, t1);
        assert!(wire2.to_frame().is_ok());
        assert!(t2 > t1);
        assert_eq!(cam.frames_received, 1);
        assert_eq!(lcd.frames_sent, 1);
    }

    #[test]
    fn owned_roundtrip_matches_borrowing_roundtrip() {
        let f = frame(64, 64, 7);
        let wire = WireFrame::from_frame(&f);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let (rx_ref, t_ref) = cam.receive(&wire, SimTime::ZERO).unwrap();
        let (rx_own, t_own) = cam.receive_owned(wire, SimTime::ZERO).unwrap();
        assert_eq!(rx_ref, rx_own);
        assert_eq!(t_ref, t_own);
        assert_eq!(cam.frames_received, 2);
        let mut lcd = LcdDriver::new(50.0e6, 27);
        let (w_ref, _) = lcd.send(&rx_ref, SimTime::ZERO);
        let (w_own, _) = lcd.send_owned(rx_own, SimTime::ZERO);
        assert_eq!(w_ref, w_own);
        assert_eq!(lcd.frames_sent, 2);
    }

    #[test]
    fn corrupted_wire_counted_and_rejected_owned() {
        let f = frame(32, 32, 9);
        let mut wire = WireFrame::from_frame(&f);
        wire.corrupt_bit(5, 1);
        let mut cam = CamGeneric::new(50.0e6, 27);
        assert!(cam.receive_owned(wire, SimTime::ZERO).is_err());
        assert_eq!(cam.crc_errors, 1);
        assert_eq!(cam.frames_received, 0);
    }

    #[test]
    fn corrupted_wire_counted_and_rejected() {
        let f = frame(32, 32, 2);
        let mut wire = WireFrame::from_frame(&f);
        wire.corrupt_bit(5, 1);
        let mut cam = CamGeneric::new(50.0e6, 27);
        assert!(cam.receive(&wire, SimTime::ZERO).is_err());
        assert_eq!(cam.crc_errors, 1);
        assert_eq!(cam.frames_received, 0);
    }

    #[test]
    fn rx_time_matches_wire_rate() {
        let f = frame(1024, 1024, 3);
        let wire = WireFrame::from_frame(&f);
        let mut cam = CamGeneric::new(50.0e6, 27);
        let (_, t) = cam.receive(&wire, SimTime::ZERO).unwrap();
        assert!((t.as_ms() - 21.6).abs() < 0.2, "{} ms", t.as_ms());
    }
}
