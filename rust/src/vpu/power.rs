//! VPU power model (paper Fig. 5): activity-based decomposition.
//!
//! `P = P_base + P_leon * leon_duty + P_shave_each * shaves * shave_duty
//!    + P_dram * dram_duty + P_iface * iface_duty`
//!
//! Unit powers are calibrated so that (paper §IV):
//! * SHAVE benchmark executions land in 0.8–1.0 W,
//! * LEON baseline executions land in 0.6–0.7 W,
//! * FPS/W of SHAVE vs LEON is ~11x for binning and up to ~58x for conv,
//! * and the per-benchmark ordering follows arithmetic intensity.

use crate::vpu::cost::BenchKind;

/// Unit power figures (Watts).
#[derive(Clone, Copy, Debug)]
pub struct PowerModel {
    /// Always-on: LEON system core, clocks, DRAM refresh, peripherals.
    pub base_w: f64,
    /// One LEON running application code at full tilt.
    pub leon_active_w: f64,
    /// One SHAVE at full utilization.
    pub shave_active_w: f64,
    /// DRAM at full activity.
    pub dram_active_w: f64,
    /// CIF+LCD engines during transfers.
    pub iface_active_w: f64,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel {
            base_w: 0.52,
            leon_active_w: 0.10,
            shave_active_w: 0.031,
            dram_active_w: 0.09,
            iface_active_w: 0.03,
        }
    }
}

/// Activity duties for one benchmark execution window.
#[derive(Clone, Copy, Debug)]
pub struct Activity {
    pub leon_duty: f64,
    pub shaves_active: usize,
    pub shave_duty: f64,
    pub dram_duty: f64,
    pub iface_duty: f64,
}

impl PowerModel {
    pub fn power(&self, a: &Activity) -> f64 {
        self.base_w
            + self.leon_active_w * a.leon_duty
            + self.shave_active_w * a.shaves_active as f64 * a.shave_duty
            + self.dram_active_w * a.dram_duty
            + self.iface_active_w * a.iface_duty
    }

    /// Activity profile of a SHAVE-accelerated benchmark execution on
    /// the paper's full 12-SHAVE part.
    pub fn shave_activity(&self, kind: BenchKind) -> Activity {
        self.shave_activity_for(kind, 12)
    }

    /// Activity profile on a node with `n_shaves` vector cores
    /// (ISSUE 8): duties are per-core properties of the kernel, so a
    /// smaller part draws proportionally less SHAVE power while base /
    /// LEON / DRAM terms stay put.
    pub fn shave_activity_for(&self, kind: BenchKind, n_shaves: usize) -> Activity {
        self.shave_activity_for_precision(kind, n_shaves, crate::Precision::F32)
    }

    /// Precision-aware activity profile (ISSUE 10): the int8 CNN
    /// finishes each MAC window in half the cycles, so per unit time it
    /// leans harder on DRAM (higher memory-boundedness) while the MAC
    /// issue slots are slightly less saturated (the requantize stage
    /// interleaves). Every non-CNN kind — and the f32 CNN — is bitwise
    /// the legacy profile.
    pub fn shave_activity_for_precision(
        &self,
        kind: BenchKind,
        n_shaves: usize,
        precision: crate::Precision,
    ) -> Activity {
        // DRAM duty tracks memory-boundedness; SHAVE duty the schedule
        // balance; LEON orchestrates (low duty).
        let (shave_duty, dram_duty) = match (kind, precision) {
            (BenchKind::Binning, _) => (0.88, 1.00), // bandwidth-bound
            (BenchKind::Conv { k }, _) => {
                let k = k as f64;
                // More taps -> more compute-bound, less DRAM-relative.
                (0.95, (0.9 - 0.03 * k).max(0.4))
            }
            (BenchKind::Render, _) => (0.93, 0.55),
            (BenchKind::Cnn, crate::Precision::F32) => (0.97, 0.70),
            (BenchKind::Cnn, crate::Precision::Int8) => (0.94, 0.82),
            // Integer predict/code: steady streaming reads, byte writes.
            (BenchKind::Ccsds, _) => (0.90, 0.85),
        };
        Activity {
            leon_duty: 0.25,
            shaves_active: n_shaves,
            shave_duty,
            dram_duty,
            iface_duty: 0.0,
        }
    }

    /// Activity profile of the LEON scalar baseline.
    pub fn leon_activity(&self, kind: BenchKind) -> Activity {
        let dram_duty = match kind {
            BenchKind::Binning => 0.85,
            BenchKind::Conv { .. } => 0.45,
            BenchKind::Render => 0.5,
            BenchKind::Cnn => 0.6,
            BenchKind::Ccsds => 0.7,
        };
        Activity {
            leon_duty: 1.0,
            shaves_active: 0,
            shave_duty: 0.0,
            dram_duty,
            iface_duty: 0.0,
        }
    }

    pub fn shave_power(&self, kind: BenchKind) -> f64 {
        self.power(&self.shave_activity(kind))
    }

    /// Per-node SHAVE power (ISSUE 8): the fleet's smaller parts burn
    /// fewer active-core watts. `shave_power_for(k, 12)` is bitwise
    /// `shave_power(k)`.
    pub fn shave_power_for(&self, kind: BenchKind, n_shaves: usize) -> f64 {
        self.power(&self.shave_activity_for(kind, n_shaves))
    }

    /// Precision-aware per-node SHAVE power (ISSUE 10).
    /// `shave_power_for_precision(k, n, F32)` is bitwise
    /// `shave_power_for(k, n)`.
    pub fn shave_power_for_precision(
        &self,
        kind: BenchKind,
        n_shaves: usize,
        precision: crate::Precision,
    ) -> f64 {
        self.power(&self.shave_activity_for_precision(kind, n_shaves, precision))
    }

    pub fn leon_power(&self, kind: BenchKind) -> f64 {
        self.power(&self.leon_activity(kind))
    }

    /// Added draw of the background ECC scrubber (ISSUE 9
    /// `recovery::Strategy::Scrub`): one DRAM sweep every `period`
    /// frames keeps the memory interface busy for roughly `1/period`
    /// of the frame window, so the extra power is `dram_active_w /
    /// period`. Documented simplification: the true duty is
    /// `pass_time / frame_time`, but power is annotated before frame
    /// wall time is known; with the default period the error is under
    /// 15 mW. Kept out of [`PowerModel::shave_activity_for`] so the
    /// no-scrub envelopes stay bitwise.
    pub fn scrub_power(&self, period: u32) -> f64 {
        if period == 0 {
            return 0.0;
        }
        self.dram_active_w / period as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VpuConfig;
    use crate::vpu::cost::{workloads, CostModel};

    fn all_kinds() -> Vec<BenchKind> {
        vec![
            BenchKind::Binning,
            BenchKind::Conv { k: 3 },
            BenchKind::Conv { k: 7 },
            BenchKind::Conv { k: 13 },
            BenchKind::Render,
            BenchKind::Cnn,
            BenchKind::Ccsds,
        ]
    }

    #[test]
    fn shave_power_in_paper_envelope() {
        let pm = PowerModel::default();
        for kind in all_kinds() {
            let p = pm.shave_power(kind);
            assert!((0.8..=1.0).contains(&p), "{kind:?}: {p} W");
        }
    }

    #[test]
    fn leon_power_in_paper_envelope() {
        let pm = PowerModel::default();
        for kind in all_kinds() {
            let p = pm.leon_power(kind);
            assert!((0.6..=0.7).contains(&p), "{kind:?}: {p} W");
        }
    }

    #[test]
    fn fps_per_watt_ratio_binning_11x() {
        let pm = PowerModel::default();
        let cm = CostModel::new(VpuConfig::myriad2());
        let w = workloads::binning_4mp();
        let k = BenchKind::Binning;
        let shave = 1.0 / cm.shave_time_ideal(k, &w).as_secs() / pm.shave_power(k);
        let leon = 1.0 / cm.leon_time(k, &w).as_secs() / pm.leon_power(k);
        let ratio = shave / leon;
        assert!((9.0..=13.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn fps_per_watt_ratio_conv_up_to_58x() {
        let pm = PowerModel::default();
        let cm = CostModel::new(VpuConfig::myriad2());
        let w = workloads::conv_1mp();
        let k = BenchKind::Conv { k: 13 };
        let shave = 1.0 / cm.shave_time_ideal(k, &w).as_secs() / pm.shave_power(k);
        let leon = 1.0 / cm.leon_time(k, &w).as_secs() / pm.leon_power(k);
        let ratio = shave / leon;
        assert!((45.0..=62.0).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn cnn_is_the_hungriest_shave_benchmark() {
        let pm = PowerModel::default();
        let p_cnn = pm.shave_power(BenchKind::Cnn);
        for kind in [BenchKind::Binning, BenchKind::Render] {
            assert!(p_cnn >= pm.shave_power(kind), "{kind:?}");
        }
    }

    #[test]
    fn per_node_shave_power_scales_with_core_count() {
        let pm = PowerModel::default();
        for kind in all_kinds() {
            let full = pm.shave_power_for(kind, 12);
            assert_eq!(full, pm.shave_power(kind), "12-SHAVE path is bitwise legacy");
            let small = pm.shave_power_for(kind, 4);
            assert!(small < full, "{kind:?}: {small} !< {full}");
            assert!(small > pm.base_w, "{kind:?}: active node above baseline");
        }
    }

    #[test]
    fn int8_cnn_power_stays_in_envelope_and_f32_is_bitwise_legacy() {
        let pm = PowerModel::default();
        let k = BenchKind::Cnn;
        let p8 = pm.shave_power_for_precision(k, 12, crate::Precision::Int8);
        assert!((0.8..=1.0).contains(&p8), "{p8} W");
        assert_ne!(p8, pm.shave_power(k), "int8 has its own activity profile");
        for kind in all_kinds() {
            assert_eq!(
                pm.shave_power_for_precision(kind, 12, crate::Precision::F32),
                pm.shave_power_for(kind, 12),
                "{kind:?}: f32 path is bitwise legacy"
            );
            if !matches!(kind, BenchKind::Cnn) {
                assert_eq!(
                    pm.shave_power_for_precision(kind, 12, crate::Precision::Int8),
                    pm.shave_power_for(kind, 12),
                    "{kind:?}: only the CNN has a quantized path"
                );
            }
        }
        // Energy per frame drops ~2x: near-equal draw at half the time.
        assert!((p8 - pm.shave_power(k)).abs() < 0.05);
    }

    #[test]
    fn scrub_power_is_a_small_dram_duty_term() {
        let pm = PowerModel::default();
        assert_eq!(pm.scrub_power(0), 0.0, "period 0 = scrubber off");
        let p8 = pm.scrub_power(8);
        assert!((p8 - pm.dram_active_w / 8.0).abs() < 1e-12);
        assert!(p8 < 0.02, "amortized scrub stays under 20 mW: {p8}");
        assert!(pm.scrub_power(2) > p8, "shorter period draws more");
    }

    #[test]
    fn idle_baseline_below_loaded() {
        let pm = PowerModel::default();
        let idle = pm.power(&Activity {
            leon_duty: 0.05,
            shaves_active: 0,
            shave_duty: 0.0,
            dram_duty: 0.05,
            iface_duty: 0.0,
        });
        assert!(idle < 0.6);
        assert!(idle > 0.4);
    }
}
