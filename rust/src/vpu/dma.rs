//! The Myriad2 DMA engine (paper Fig. 3): moves frame bands DRAM <-> CMX
//! for the SHAVEs, and output data back.
//!
//! Transaction model: each descriptor costs a fixed setup plus
//! bytes/bandwidth. The SHAVE kernels double-buffer bands, so in the
//! benchmark timing the DMA is overlapped except for the first fill
//! (`pipeline_fill_time`); the non-overlapped check is still useful to
//! confirm DMA is not the bottleneck (it is not, at 1.5 GB/s).

use crate::fabric::clock::SimTime;

/// DMA engine timing parameters + cumulative stats.
#[derive(Clone, Debug)]
pub struct DmaEngine {
    pub bytes_per_s: f64,
    /// Descriptor setup overhead per transfer.
    pub setup: SimTime,
    pub transfers: u64,
    pub bytes_moved: u64,
}

impl DmaEngine {
    pub fn new(bytes_per_s: f64) -> DmaEngine {
        DmaEngine {
            bytes_per_s,
            setup: SimTime::from_us(1.5),
            transfers: 0,
            bytes_moved: 0,
        }
    }

    /// Duration of a single transfer of `bytes`.
    pub fn transfer_time(&self, bytes: usize) -> SimTime {
        self.setup + SimTime::from_secs(bytes as f64 / self.bytes_per_s)
    }

    /// Account a transfer.
    pub fn transfer(&mut self, bytes: usize) -> SimTime {
        self.transfers += 1;
        self.bytes_moved += bytes as u64;
        self.transfer_time(bytes)
    }

    /// Latency to fill the first band of a double-buffered pipeline
    /// (the only non-overlapped DMA cost in steady state).
    pub fn pipeline_fill_time(&self, band_bytes: usize) -> SimTime {
        self.transfer_time(band_bytes)
    }

    /// Whether DMA bandwidth can keep `n_cores` busy given per-band
    /// compute time and band size (double-buffering feasibility).
    pub fn sustains(&self, band_bytes: usize, band_compute: SimTime, n_cores: usize) -> bool {
        // While one band computes, the engine must stage the next band
        // for each core.
        let stage = self.transfer_time(band_bytes).as_secs() * n_cores as f64;
        stage <= band_compute.as_secs() * n_cores as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales_with_bytes() {
        let d = DmaEngine::new(1.5e9);
        let t1 = d.transfer_time(1 << 20);
        let t4 = d.transfer_time(4 << 20);
        // 1 MiB at 1.5 GB/s ~ 0.7 ms.
        assert!((t1.as_ms() - 0.7).abs() < 0.01, "{}", t1.as_ms());
        assert!(t4.as_secs() > 3.9 * t1.as_secs());
    }

    #[test]
    fn stats_accumulate() {
        let mut d = DmaEngine::new(1.5e9);
        d.transfer(1000);
        d.transfer(2000);
        assert_eq!(d.transfers, 2);
        assert_eq!(d.bytes_moved, 3000);
    }

    #[test]
    fn dma_not_bottleneck_for_paper_benchmarks() {
        // Binning: 12 cores each staging 2048x57-ish byte bands while
        // computing ~0.25 ms per band — DMA sustains easily.
        let d = DmaEngine::new(1.5e9);
        let band_bytes = 2048 * 64; // 128 KiB band
        let band_compute = SimTime::from_us(250.0);
        assert!(d.sustains(band_bytes, band_compute, 12));
    }

    #[test]
    fn tiny_transfers_dominated_by_setup() {
        let d = DmaEngine::new(1.5e9);
        let t = d.transfer_time(64);
        assert!((t.as_us() - 1.5).abs() < 0.1);
    }
}
