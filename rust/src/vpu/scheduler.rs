//! Scheduling, at both levels of the topology.
//!
//! **Band scheduling across the 12 SHAVEs** (paper §III-C):
//!
//! * Binning/conv use a **static** split: "we divide the ... input image
//!   into 36 bands, and each SHAVE is assigned 3 bands" — round-robin
//!   band assignment, makespan = slowest core.
//! * Rendering uses the **dynamic** queue: "each SHAVE is dynamically
//!   assigned a new band to render, upon finishing its previous one" —
//!   greedy list scheduling, which absorbs content skew.
//!
//! **Frame dispatch across N VPU nodes** (ISSUE 5, mirroring the MPAI
//! follow-up's multi-accelerator scaling): [`SchedPolicy`] selects how
//! `coordinator::stream`'s dispatch stage routes frames to nodes —
//! the same static/dynamic split, one level up.

use crate::fabric::clock::SimTime;

/// Frame-dispatch policy across the VPU nodes of the topology.
///
/// Since ISSUE 7 every policy is decided by the virtual-time event
/// loop in `coordinator::traffic` *before* any worker thread starts, so
/// node attribution is deterministic for all of them — a pure function
/// of the traffic config, seed and service model, never of wallclock
/// timing. (The PR-5 dispatcher's 50 ms wall-clock condvar anti-wedge
/// is long gone; no dispatch path sleeps on or reads real time, and
/// `Eft` keeps that invariant — its finish-time predictions are pure
/// virtual-time arithmetic.)
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedPolicy {
    /// Static: admitted frame `i` goes to node `i % N` (with traffic
    /// off, admission order is frame order — the legacy assignment,
    /// bit-exact against pre-ISSUE-7 sweeps). With a fixed fault seed,
    /// an N-node round-robin sweep carries bit-identical per-frame
    /// results to the single-node sweep (the fault draws are
    /// node-independent by construction).
    #[default]
    RoundRobin,
    /// Dynamic: when a node frees up in virtual time it takes the
    /// highest-priority queued frame (alert before standard before
    /// bulk; lowest-index idle node wins ties) — the greedy list
    /// scheduler of the SHAVE band queue, one level up. Per-frame
    /// results stay seed-deterministic (a frame computes and faults
    /// identically on every node). No node can starve: an idle node
    /// always takes the next admitted frame.
    LeastLoaded,
    /// Cost-aware (ISSUE 8): each frame goes to the node with the
    /// earliest predicted *finish* time — queued backlog priced by that
    /// node's own cost model, plus a host-bus-grant estimate — not the
    /// shortest queue. On a heterogeneous fleet a short queue on a
    /// half-clock node routinely finishes later than a longer queue on
    /// a full-speed one, which is exactly the case `lld` gets wrong.
    /// Idle nodes with empty queues steal queued work from the most
    /// backlogged peer (bounded: one frame per free event), so bounded
    /// per-node queues can't strand frames behind a slow node.
    Eft,
}

impl SchedPolicy {
    /// Parse the CLI spelling (`rr` / `lld` / `eft`, long forms
    /// accepted).
    pub fn parse(s: &str) -> Option<SchedPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "rr" | "round-robin" | "roundrobin" => Some(SchedPolicy::RoundRobin),
            "lld" | "least-loaded" | "leastloaded" => Some(SchedPolicy::LeastLoaded),
            "eft" | "earliest-finish" | "earliestfinish" => Some(SchedPolicy::Eft),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SchedPolicy::RoundRobin => "rr",
            SchedPolicy::LeastLoaded => "lld",
            SchedPolicy::Eft => "eft",
        }
    }
}

/// Frames the static round-robin assignment hands node `lane` out of
/// `n_frames` over `n_nodes` (frames `lane, lane + N, lane + 2N, ...`).
pub fn rr_share(n_frames: usize, n_nodes: usize, lane: usize) -> usize {
    if lane >= n_nodes || n_frames <= lane {
        return 0;
    }
    (n_frames - lane).div_ceil(n_nodes)
}

/// Makespan (seconds -> SimTime) of a static round-robin assignment of
/// `band_cycles` to `n_cores` at `clock_hz`.
pub fn static_makespan(band_cycles: &[f64], n_cores: usize, clock_hz: f64) -> SimTime {
    assert!(n_cores > 0);
    let mut per_core = vec![0.0f64; n_cores];
    for (i, &c) in band_cycles.iter().enumerate() {
        per_core[i % n_cores] += c;
    }
    let worst = per_core.iter().cloned().fold(0.0, f64::max);
    SimTime::from_secs(worst / clock_hz)
}

/// Makespan of greedy dynamic scheduling (each core pulls the next band
/// when free), plus the per-core busy times for utilization reporting.
pub fn dynamic_makespan_detail(
    band_cycles: &[f64],
    n_cores: usize,
    clock_hz: f64,
) -> (SimTime, Vec<f64>) {
    assert!(n_cores > 0);
    // Min-heap of (finish_cycles, core) — emulated with a sorted vec since
    // n_cores is tiny.
    let mut core_free = vec![0.0f64; n_cores];
    for &c in band_cycles {
        // Next free core.
        let (idx, _) = core_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        core_free[idx] += c;
    }
    let worst = core_free.iter().cloned().fold(0.0, f64::max);
    (
        SimTime::from_secs(worst / clock_hz),
        core_free.iter().map(|c| c / clock_hz).collect(),
    )
}

pub fn dynamic_makespan(band_cycles: &[f64], n_cores: usize, clock_hz: f64) -> SimTime {
    dynamic_makespan_detail(band_cycles, n_cores, clock_hz).0
}

/// Scheduling efficiency: ideal parallel time / achieved makespan.
pub fn efficiency(band_cycles: &[f64], n_cores: usize, makespan: SimTime, clock_hz: f64) -> f64 {
    let total: f64 = band_cycles.iter().sum();
    let ideal = total / n_cores as f64 / clock_hz;
    ideal / makespan.as_secs()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    const F: f64 = 600.0e6;

    #[test]
    fn uniform_bands_perfectly_balanced() {
        // Paper's binning split: 36 uniform bands on 12 cores = 3 each.
        let bands = vec![1000.0; 36];
        let m = static_makespan(&bands, 12, F);
        assert_eq!(m, SimTime::from_secs(3000.0 / F));
        assert_eq!(m, dynamic_makespan(&bands, 12, F));
    }

    #[test]
    fn dynamic_beats_static_on_skewed_content() {
        // One heavy band at the front of each core's round-robin slice.
        let mut bands = vec![100.0; 36];
        bands[0] = 5000.0;
        bands[12] = 5000.0; // static lands both on core 0
        let s = static_makespan(&bands, 12, F);
        let d = dynamic_makespan(&bands, 12, F);
        assert!(d < s, "dynamic {d:?} !< static {s:?}");
    }

    #[test]
    fn single_core_sums_everything() {
        let bands = vec![10.0, 20.0, 30.0];
        assert_eq!(static_makespan(&bands, 1, F), SimTime::from_secs(60.0 / F));
        assert_eq!(dynamic_makespan(&bands, 1, F), SimTime::from_secs(60.0 / F));
    }

    #[test]
    fn efficiency_of_balanced_schedule_is_one() {
        let bands = vec![500.0; 24];
        let m = dynamic_makespan(&bands, 12, F);
        let e = efficiency(&bands, 12, m, F);
        // SimTime quantizes to integer picoseconds; allow that rounding.
        assert!((e - 1.0).abs() < 1e-5, "{e}");
    }

    #[test]
    fn prop_makespan_bounds() {
        // Both schedulers respect the lower bound max(total/n, max_band);
        // greedy list scheduling additionally satisfies the Graham bound
        // (2 - 1/n) x lower; static is bounded by the serial total.
        // (Note: static round-robin *can* beat greedy on adversarial
        // orders, so no ordering between the two is asserted.)
        check("scheduler makespan bounds", 64, |g: &mut Gen| {
            let n_cores = g.int_in(1, 12);
            let bands: Vec<f64> =
                g.vec(1..=60, |g| g.f64_in(1.0, 10_000.0));
            let total: f64 = bands.iter().sum();
            let maxb = bands.iter().cloned().fold(0.0, f64::max);
            let lower = (total / n_cores as f64).max(maxb) / F;
            let d = dynamic_makespan(&bands, n_cores, F).as_secs();
            let s = static_makespan(&bands, n_cores, F).as_secs();
            let eps = 1e-9 * lower.max(1e-12) + 1e-12;
            let graham = lower * (2.0 - 1.0 / n_cores as f64) + eps;
            d >= lower - eps
                && d <= graham
                && s >= lower - eps
                && s <= total / F + eps
        });
    }

    #[test]
    fn sched_policy_parses_cli_spellings() {
        assert_eq!(SchedPolicy::parse("rr"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("round-robin"), Some(SchedPolicy::RoundRobin));
        assert_eq!(SchedPolicy::parse("LLD"), Some(SchedPolicy::LeastLoaded));
        assert_eq!(SchedPolicy::parse("least-loaded"), Some(SchedPolicy::LeastLoaded));
        assert_eq!(SchedPolicy::parse("eft"), Some(SchedPolicy::Eft));
        assert_eq!(SchedPolicy::parse("EFT"), Some(SchedPolicy::Eft));
        assert_eq!(SchedPolicy::parse("earliest-finish"), Some(SchedPolicy::Eft));
        assert_eq!(SchedPolicy::parse("fifo"), None);
        assert_eq!(SchedPolicy::default(), SchedPolicy::RoundRobin);
        assert_eq!(SchedPolicy::LeastLoaded.name(), "lld");
        assert_eq!(SchedPolicy::Eft.name(), "eft");
    }

    #[test]
    fn rr_share_partitions_all_frames() {
        for (frames, nodes) in [(64usize, 1usize), (64, 2), (64, 4), (7, 3), (2, 4), (0, 2)] {
            let total: usize = (0..nodes).map(|l| rr_share(frames, nodes, l)).sum();
            assert_eq!(total, frames, "{frames} frames over {nodes} nodes");
        }
        assert_eq!(rr_share(7, 3, 0), 3); // frames 0, 3, 6
        assert_eq!(rr_share(7, 3, 1), 2); // frames 1, 4
        assert_eq!(rr_share(7, 3, 2), 2); // frames 2, 5
        assert_eq!(rr_share(2, 4, 3), 0); // more nodes than frames
    }

    #[test]
    fn prop_both_schedulers_process_all_work() {
        // Conservation: per-core busy times must sum to the total work.
        check("scheduler conserves work", 64, |g: &mut Gen| {
            let n_cores = g.int_in(1, 12);
            let bands: Vec<f64> = g.vec(1..=48, |g| g.f64_in(1.0, 5000.0));
            let total: f64 = bands.iter().sum();
            let (_, busy) = dynamic_makespan_detail(&bands, n_cores, F);
            let busy_total: f64 = busy.iter().map(|t| t * F).sum();
            (busy_total - total).abs() < 1e-6 * total.max(1.0)
        });
    }
}
