//! Myriad2 VPU model (paper §II, §III-B, Fig. 3).
//!
//! The VPU side of the co-processor: 2 general-purpose LEON cores, 12
//! SHAVE vector cores @600 MHz, a DMA engine between DRAM and the 2 MB
//! CMX scratchpad, and the CamGeneric/LCD driver stacks.
//!
//! Division of labour with the rest of the crate:
//! * **numerics** — executed for real through the AOT Pallas artifacts
//!   (see `runtime`); this module never computes pixels.
//! * **time** — [`cost`] provides per-benchmark cycle models calibrated
//!   against the paper's measured Table II / speedup numbers; [`scheduler`]
//!   turns per-band costs into makespans on the 12 SHAVEs; [`dma`] and
//!   [`memory`] account data movement and capacity.
//! * **power** — [`power`] reproduces Fig. 5 from per-unit activity.

pub mod cost;
pub mod dma;
pub mod drivers;
pub mod memory;
pub mod power;
pub mod scheduler;

pub use cost::{BenchKind, CostModel, Workload};
pub use scheduler::{dynamic_makespan, static_makespan, SchedPolicy};
