//! System configuration: the knobs of the testbed in paper §II-§IV.
//!
//! Defaults reproduce the paper's evaluated operating point:
//! CIF/LCD @ 50 MHz, 12 SHAVEs @ 600 MHz, 2 LEONs, XCKU060 framing FPGA.

use crate::error::{Error, Result};

/// Clock + sizing for one pixel interface (CIF or LCD).
#[derive(Clone, Copy, Debug)]
pub struct IfaceConfig {
    /// Pixel clock in Hz; the paper validates up to 50 MHz full-frame,
    /// 100 MHz (CIF) / 90 MHz (LCD) with reduced buffers.
    pub pixel_clock_hz: f64,
    /// Pixel FIFO depth (pixels) between FSM and Tx/Rx.
    pub pixel_fifo_depth: usize,
    /// Image buffer capacity in 32-bit words (BRAM-backed).
    pub image_buffer_words: usize,
    /// Horizontal blanking (porch) overhead per line, in pixel clocks.
    /// Calibrated so a 2048x2048@8bpp frame takes ~85 ms at 50 MHz
    /// (paper Table II).
    pub porch_cycles_per_line: usize,
}

impl IfaceConfig {
    /// Paper operating point: 50 MHz, full-frame buffers.
    pub fn paper_50mhz() -> IfaceConfig {
        IfaceConfig {
            pixel_clock_hz: 50.0e6,
            pixel_fifo_depth: 1024,
            // 1Mi words = 4 MiB: buffers a 4 MPixel 8bpp or 2 MPixel 16bpp
            // frame (paper: "due to the FPGA memory resources, we
            // transmitted ... 16-bit frames with up to 1024x1024 size").
            image_buffer_words: 1 << 20,
            porch_cycles_per_line: 27,
        }
    }

    /// Reduced-buffer high-frequency point (paper: CIF@100/LCD@90 MHz with
    /// frames up to 64x64 @16bpp).
    pub fn reduced_100mhz(pixel_clock_hz: f64) -> IfaceConfig {
        IfaceConfig {
            pixel_clock_hz,
            pixel_fifo_depth: 256,
            image_buffer_words: 2048, // 8 KiB
            porch_cycles_per_line: 27,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(1.0e6..=200.0e6).contains(&self.pixel_clock_hz) {
            return Err(Error::Config(format!(
                "pixel clock {} Hz out of range",
                self.pixel_clock_hz
            )));
        }
        if self.pixel_fifo_depth == 0 || self.image_buffer_words == 0 {
            return Err(Error::Config("zero-sized fifo/buffer".into()));
        }
        Ok(())
    }
}

/// Myriad2 VPU model parameters (paper §II/§III-B + Myriad2 datasheet).
#[derive(Clone, Copy, Debug)]
pub struct VpuConfig {
    /// SHAVE vector cores: "the 12 SHAVE cores (VLIW & SIMD, 600MHz)".
    pub n_shaves: usize,
    pub shave_clock_hz: f64,
    /// General-purpose LEON cores (LEON4: one for I/O, one for compute
    /// management in Masked mode).
    pub n_leons: usize,
    pub leon_clock_hz: f64,
    /// CMX scratchpad (SPM) capacity.
    pub cmx_bytes: usize,
    /// On-package DRAM capacity (frame buffers + weight store live
    /// here; masked mode double-buffers four frame-sized regions).
    pub dram_bytes: usize,
    /// DRAM->DRAM buffered-copy rate for Masked-mode double buffering.
    /// Calibrated from the paper: "copying an 1MPixel frame requires
    /// ~42ms" => 25 Mpixel/s (DESIGN.md §4).
    pub dram_copy_mpx_per_s: f64,
    /// DMA engine bandwidth DRAM<->CMX (bytes/s).
    pub dma_bytes_per_s: f64,
}

impl VpuConfig {
    pub fn myriad2() -> VpuConfig {
        VpuConfig {
            n_shaves: 12,
            shave_clock_hz: 600.0e6,
            n_leons: 2,
            leon_clock_hz: 230.0e6, // LEON4 OS/RT clock on Myriad2
            cmx_bytes: 2 * 1024 * 1024,
            dram_bytes: 512 * 1024 * 1024, // MA2450 on-package LPDDR3
            dram_copy_mpx_per_s: 25.0e6,
            dma_bytes_per_s: 1.5e9,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_shaves == 0 || self.n_leons == 0 {
            return Err(Error::Config("VPU needs cores".into()));
        }
        if self.cmx_bytes < 64 * 1024 {
            return Err(Error::Config("CMX implausibly small".into()));
        }
        if self.dram_bytes < 16 * 1024 * 1024 {
            return Err(Error::Config(
                "DRAM implausibly small for masked double-buffering".into(),
            ));
        }
        Ok(())
    }
}

/// Default per-group DRAM when a fleet spec omits the `:<n>MB`
/// suffix — the MA2450 fit, matching [`VpuConfig::myriad2`].
pub const FLEET_DEFAULT_DRAM_MB: usize = 512;

/// One homogeneous group of nodes inside a [`FleetSpec`]:
/// `<count>x<clock>MHz:<shaves>[:<dram>MB][@<rate>]`.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetGroup {
    pub count: usize,
    pub clock_mhz: f64,
    pub shaves: usize,
    pub dram_mb: usize,
    /// Per-node upset-rate override (ISSUE 9): the `@rate` suffix
    /// models this group's silicon cross-section — rad-hard parts next
    /// to COTS parts in one fleet. `None` inherits the fault plan's
    /// global rate; the override applies to the node's wire hops *and*
    /// memory domains.
    pub upset_rate: Option<f64>,
}

/// A heterogeneous VPU fleet (ISSUE 8): comma-separated groups, e.g.
/// `2x600MHz:12,1x300MHz:4` — two full Myriad2-class nodes plus one
/// half-clock 4-SHAVE part. Parsed from `--fleet` /
/// `SPACECODESIGN_FLEET` via [`ResolvedConfig`]; node `i`'s
/// [`VpuConfig`] comes from [`FleetSpec::node_vpu`], so every node's
/// cost/power/DES models price its own silicon honestly.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetSpec {
    pub groups: Vec<FleetGroup>,
}

impl FleetSpec {
    /// Parse the CLI/env spelling. Round-trips through
    /// [`std::fmt::Display`]; rejects malformed or implausible specs.
    pub fn parse(s: &str) -> Result<FleetSpec> {
        let bad = |part: &str, why: &str| {
            Error::Config(format!("bad fleet group '{part}': {why} (want <count>x<clock>MHz:<shaves>[:<dram>MB][@<rate>])"))
        };
        let mut groups = Vec::new();
        for part in s.split(',') {
            let part = part.trim();
            // The upset-rate suffix splits off first so the core
            // fields parse exactly as before it existed.
            let (core, upset_rate) = match part.split_once('@') {
                None => (part, None),
                Some((core, r)) => {
                    let rate: f64 = r
                        .trim()
                        .parse()
                        .map_err(|_| bad(part, "bad upset rate"))?;
                    (core.trim_end(), Some(rate))
                }
            };
            let (count_s, rest) = core
                .split_once(['x', 'X'])
                .ok_or_else(|| bad(part, "missing 'x'"))?;
            let count: usize = count_s
                .trim()
                .parse()
                .map_err(|_| bad(part, "bad node count"))?;
            let mut fields = rest.split(':');
            let clock_s = fields.next().unwrap_or("").trim();
            let clock_s = clock_s
                .strip_suffix("MHz")
                .or_else(|| clock_s.strip_suffix("mhz"))
                .or_else(|| clock_s.strip_suffix("MHZ"))
                .unwrap_or(clock_s);
            let clock_mhz: f64 = clock_s
                .parse()
                .map_err(|_| bad(part, "bad clock"))?;
            let shaves: usize = fields
                .next()
                .ok_or_else(|| bad(part, "missing SHAVE count"))?
                .trim()
                .parse()
                .map_err(|_| bad(part, "bad SHAVE count"))?;
            let dram_mb = match fields.next() {
                None => FLEET_DEFAULT_DRAM_MB,
                Some(d) => {
                    let d = d.trim();
                    d.strip_suffix("MB")
                        .or_else(|| d.strip_suffix("mb"))
                        .unwrap_or(d)
                        .parse()
                        .map_err(|_| bad(part, "bad DRAM size"))?
                }
            };
            if fields.next().is_some() {
                return Err(bad(part, "trailing fields"));
            }
            groups.push(FleetGroup { count, clock_mhz, shaves, dram_mb, upset_rate });
        }
        let spec = FleetSpec { groups };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        if self.groups.is_empty() {
            return Err(Error::Config("empty fleet spec".into()));
        }
        for g in &self.groups {
            if g.count == 0 {
                return Err(Error::Config("fleet group with zero nodes".into()));
            }
            if g.shaves == 0 || g.shaves > 64 {
                return Err(Error::Config(format!(
                    "fleet SHAVE count {} out of range 1..=64",
                    g.shaves
                )));
            }
            if !(50.0..=2000.0).contains(&g.clock_mhz) {
                return Err(Error::Config(format!(
                    "fleet clock {} MHz out of range 50..=2000",
                    g.clock_mhz
                )));
            }
            if g.dram_mb < 16 {
                return Err(Error::Config(format!(
                    "fleet DRAM {} MB implausibly small",
                    g.dram_mb
                )));
            }
            if let Some(r) = g.upset_rate {
                if !r.is_finite() || !(0.0..=1.0).contains(&r) {
                    return Err(Error::Config(format!(
                        "fleet upset rate {r} out of range 0..=1"
                    )));
                }
            }
        }
        let n = self.n_nodes();
        if n > crate::coordinator::system::MAX_VPUS {
            return Err(Error::Config(format!(
                "fleet of {n} nodes exceeds MAX_VPUS {}",
                crate::coordinator::system::MAX_VPUS
            )));
        }
        Ok(())
    }

    /// Total node count across all groups.
    pub fn n_nodes(&self) -> usize {
        self.groups.iter().map(|g| g.count).sum()
    }

    /// Per-node upset-rate overrides, indexed by topology position
    /// (ISSUE 9): feed to
    /// [`crate::iface::fault::FaultPlan::set_node_rates`]. `None`
    /// entries inherit the plan's global rates.
    pub fn node_upset_rates(&self) -> Vec<Option<f64>> {
        let mut rates = Vec::with_capacity(self.n_nodes());
        for g in &self.groups {
            rates.extend(std::iter::repeat(g.upset_rate).take(g.count));
        }
        rates
    }

    /// The [`VpuConfig`] for node `index`: the base (paper) part with
    /// this group's clock/SHAVEs/DRAM applied. The DRAM controller and
    /// DMA engine run off the same system PLL as the SHAVEs, so the
    /// buffered-copy and DMA rates scale with the clock ratio — a
    /// half-clock node double-buffers masked frames at half the rate,
    /// which the per-node Masked DES then prices. Indices beyond the
    /// spec fall back to the base part unchanged.
    pub fn node_vpu(&self, index: usize, base: &VpuConfig) -> VpuConfig {
        let mut i = index;
        for g in &self.groups {
            if i < g.count {
                let clock_hz = g.clock_mhz * 1.0e6;
                let ratio = clock_hz / base.shave_clock_hz;
                return VpuConfig {
                    n_shaves: g.shaves,
                    shave_clock_hz: clock_hz,
                    dram_bytes: g.dram_mb * 1024 * 1024,
                    dram_copy_mpx_per_s: base.dram_copy_mpx_per_s * ratio,
                    dma_bytes_per_s: base.dma_bytes_per_s * ratio,
                    ..*base
                };
            }
            i -= g.count;
        }
        *base
    }
}

impl std::fmt::Display for FleetSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, g) in self.groups.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{}x{}MHz:{}", g.count, g.clock_mhz, g.shaves)?;
            if g.dram_mb != FLEET_DEFAULT_DRAM_MB {
                write!(f, ":{}MB", g.dram_mb)?;
            }
            if let Some(r) = g.upset_rate {
                write!(f, "@{r}")?;
            }
        }
        Ok(())
    }
}

/// Whole-testbed configuration.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub cif: IfaceConfig,
    pub lcd: IfaceConfig,
    pub vpu: VpuConfig,
    /// Directory holding AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    /// Validate outputs against host groundtruth after each frame.
    pub validate: bool,
}

impl SystemConfig {
    /// The paper's evaluated configuration (Table II).
    pub fn paper() -> SystemConfig {
        SystemConfig {
            cif: IfaceConfig::paper_50mhz(),
            lcd: IfaceConfig::paper_50mhz(),
            vpu: VpuConfig::myriad2(),
            artifacts_dir: default_artifacts_dir(),
            validate: true,
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.cif.validate()?;
        self.lcd.validate()?;
        self.vpu.validate()
    }
}

/// Where a resolved runtime setting's value came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SettingSource {
    /// An explicit CLI flag — highest precedence.
    Cli,
    /// A `SPACECODESIGN_*` environment variable.
    Env,
    /// The built-in default.
    Default,
}

impl SettingSource {
    /// Lowercase label for the provenance line.
    pub fn name(self) -> &'static str {
        match self {
            SettingSource::Cli => "cli",
            SettingSource::Env => "env",
            SettingSource::Default => "default",
        }
    }
}

/// A resolved value tagged with its provenance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Setting<T> {
    pub value: T,
    pub source: SettingSource,
}

impl<T> Setting<T> {
    /// A value set by a CLI flag.
    pub fn cli(value: T) -> Setting<T> {
        Setting { value, source: SettingSource::Cli }
    }

    /// A value read from the environment.
    pub fn env(value: T) -> Setting<T> {
        Setting { value, source: SettingSource::Env }
    }

    /// The built-in default.
    pub fn fallback(value: T) -> Setting<T> {
        Setting { value, source: SettingSource::Default }
    }
}

/// CLI-side overrides feeding [`ResolvedConfig::resolve`] — `None`
/// fields fall through to the environment, then the default.
#[derive(Clone, Debug, Default)]
pub struct CliOverrides {
    pub backend: Option<crate::KernelBackend>,
    pub precision: Option<crate::Precision>,
    pub workers: Option<usize>,
    pub vpus: Option<usize>,
    pub fault_seed: Option<u64>,
    pub fault_rate: Option<f64>,
    pub fault_strategy: Option<crate::recovery::Strategy>,
    pub fleet: Option<FleetSpec>,
}

/// The one resolved runtime configuration (ISSUE 7 satellite): every
/// `SPACECODESIGN_BACKEND`/`WORKERS`/`VPUS`/`FAULT_SEED`/`FAULT_RATE`
/// knob read **once**, with documented precedence **CLI > env >
/// default**, instead of scattered per-call lookups inside library
/// code. `main` constructs it once (from its flags) and prints
/// [`ResolvedConfig::summary`] once per stream run; library callers
/// with no CLI use [`ResolvedConfig::from_env`].
#[derive(Clone, Debug)]
pub struct ResolvedConfig {
    /// Kernel tier (`SPACECODESIGN_BACKEND`; default `Optimized`).
    pub backend: Setting<crate::KernelBackend>,
    /// CNN inference precision (`--precision` /
    /// `SPACECODESIGN_PRECISION`; default `F32`, the pinned PR 9
    /// behavior). Orthogonal to `backend`: every tier has both an f32
    /// and an int8 CNN implementation.
    pub precision: Setting<crate::Precision>,
    /// Worker-pool cap (`SPACECODESIGN_WORKERS`; default `None` =
    /// auto-size from the core count).
    pub workers: Setting<Option<usize>>,
    /// Topology size (`SPACECODESIGN_VPUS`; default 1, clamped to
    /// `1..=MAX_VPUS` like the historical env read).
    pub vpus: Setting<usize>,
    /// Fault-injection seed (`SPACECODESIGN_FAULT_SEED`; default
    /// `None` = injection off).
    pub fault_seed: Setting<Option<u64>>,
    /// Per-frame fault rate (`SPACECODESIGN_FAULT_RATE`; default 0.02,
    /// mirroring `FaultPlan::from_env`). Only meaningful with a seed.
    pub fault_rate: Setting<f64>,
    /// Recovery strategy (`--strategy` /
    /// `SPACECODESIGN_FAULT_STRATEGY`; default `Resend`, the PR 4
    /// behavior). Only meaningful with a seed.
    pub fault_strategy: Setting<crate::recovery::Strategy>,
    /// Heterogeneous fleet spec (`--fleet` / `SPACECODESIGN_FLEET`;
    /// default `None` = homogeneous paper parts). When set, it defines
    /// the topology: `vpus` is derived from [`FleetSpec::n_nodes`]. An
    /// explicit `--vpus` flag beats an *ambient* env fleet (CLI > env),
    /// which then resolves to `None`.
    pub fleet: Setting<Option<FleetSpec>>,
}

impl ResolvedConfig {
    /// Resolve with CLI overrides: CLI > `SPACECODESIGN_*` env >
    /// default.
    pub fn resolve(cli: &CliOverrides) -> ResolvedConfig {
        Self::resolve_with(cli, |k| std::env::var(k).ok())
    }

    /// Resolve from the environment alone (library callers, tests).
    pub fn from_env() -> ResolvedConfig {
        Self::resolve(&CliOverrides::default())
    }

    /// The resolution core, with the environment abstracted so tests
    /// can exercise precedence without mutating process state.
    fn resolve_with(
        cli: &CliOverrides,
        env: impl Fn(&str) -> Option<String>,
    ) -> ResolvedConfig {
        let backend = match cli.backend {
            Some(b) => Setting::cli(b),
            None => match env("SPACECODESIGN_BACKEND")
                .and_then(|v| crate::KernelBackend::parse(&v))
            {
                Some(b) => Setting::env(b),
                None => Setting::fallback(crate::KernelBackend::default()),
            },
        };
        let precision = match cli.precision {
            Some(p) => Setting::cli(p),
            None => match env("SPACECODESIGN_PRECISION")
                .and_then(|v| crate::Precision::parse(&v))
            {
                Some(p) => Setting::env(p),
                None => Setting::fallback(crate::Precision::default()),
            },
        };
        let workers = match cli.workers {
            Some(w) => Setting::cli(Some(w)),
            None => match env("SPACECODESIGN_WORKERS").and_then(|v| v.parse::<usize>().ok()) {
                Some(w) => Setting::env(Some(w)),
                None => Setting::fallback(None),
            },
        };
        let fleet = match &cli.fleet {
            Some(f) => Setting::cli(Some(f.clone())),
            None => match env("SPACECODESIGN_FLEET").and_then(|v| FleetSpec::parse(&v).ok()) {
                // An explicit --vpus flag beats an ambient env fleet
                // (CLI > env): the fleet resolves away entirely so the
                // topology stays homogeneous at the requested size.
                Some(_) if cli.vpus.is_some() => Setting::fallback(None),
                Some(f) => Setting::env(Some(f)),
                None => Setting::fallback(None),
            },
        };
        let vpus = match &fleet.value {
            // A fleet defines the topology: node count comes from the
            // spec, with the spec's own provenance.
            Some(f) => Setting { value: f.n_nodes(), source: fleet.source },
            None => match cli.vpus {
                Some(v) => Setting::cli(v),
                None => match env("SPACECODESIGN_VPUS").and_then(|v| v.parse::<usize>().ok()) {
                    Some(v) => Setting::env(v.clamp(1, crate::coordinator::system::MAX_VPUS)),
                    None => Setting::fallback(1),
                },
            },
        };
        let fault_seed = match cli.fault_seed {
            Some(s) => Setting::cli(Some(s)),
            None => match env("SPACECODESIGN_FAULT_SEED").and_then(|v| v.parse::<u64>().ok()) {
                Some(s) => Setting::env(Some(s)),
                None => Setting::fallback(None),
            },
        };
        let fault_rate = match cli.fault_rate {
            Some(r) => Setting::cli(r),
            None => match env("SPACECODESIGN_FAULT_RATE").and_then(|v| v.parse::<f64>().ok()) {
                Some(r) => Setting::env(r),
                None => Setting::fallback(0.02),
            },
        };
        let fault_strategy = match cli.fault_strategy {
            Some(s) => Setting::cli(s),
            None => match env("SPACECODESIGN_FAULT_STRATEGY")
                .and_then(|v| crate::recovery::Strategy::parse(&v))
            {
                Some(s) => Setting::env(s),
                None => Setting::fallback(crate::recovery::Strategy::default()),
            },
        };
        ResolvedConfig {
            backend,
            precision,
            workers,
            vpus,
            fault_seed,
            fault_rate,
            fault_strategy,
            fleet,
        }
    }

    /// The fault configuration this resolution implies (`None` when no
    /// seed is set — injection off). The resolved strategy is applied;
    /// `memory_rate` stays at its inert default — memory-domain
    /// injection is opted into programmatically (the campaign mode
    /// does), never ambiently, so env-seeded wire-fault runs keep
    /// their pinned counters.
    pub fn fault_config(&self) -> Option<crate::iface::fault::FaultConfig> {
        self.fault_seed.value.map(|seed| {
            let mut fc =
                crate::iface::fault::FaultConfig::new(seed, self.fault_rate.value);
            fc.strategy = self.fault_strategy.value;
            fc
        })
    }

    /// The fault plan this resolution implies.
    pub fn fault_plan(&self) -> Option<crate::iface::fault::FaultPlan> {
        self.fault_config().map(crate::iface::fault::FaultPlan::new)
    }

    /// One provenance line for the stream summary: every knob's value
    /// and where it came from.
    pub fn summary(&self) -> String {
        let workers = match self.workers.value {
            Some(n) => n.to_string(),
            None => "auto".to_string(),
        };
        let faults = match self.fault_seed.value {
            Some(seed) => format!(
                "seed {seed} rate {} strategy {}",
                self.fault_rate.value,
                self.fault_strategy.value.name()
            ),
            None => "off".to_string(),
        };
        let fleet = match &self.fleet.value {
            Some(f) => f.to_string(),
            None => "off".to_string(),
        };
        format!(
            "config: backend {} [{}] | precision {} [{}] | workers {} [{}] | vpus {} [{}] | fleet {} [{}] | faults {} [{}]",
            self.backend.value.name(),
            self.backend.source.name(),
            self.precision.value.name(),
            self.precision.source.name(),
            workers,
            self.workers.source.name(),
            self.vpus.value,
            self.vpus.source.name(),
            fleet,
            self.fleet.source.name(),
            faults,
            self.fault_seed.source.name(),
        )
    }
}

/// Resolve the artifacts directory: $SPACECODESIGN_ARTIFACTS, else
/// ./artifacts relative to the crate root (where `make artifacts` puts it).
pub fn default_artifacts_dir() -> String {
    if let Ok(dir) = std::env::var("SPACECODESIGN_ARTIFACTS") {
        return dir;
    }
    // Crate root = CARGO_MANIFEST_DIR at compile time (tests, benches),
    // falling back to ./artifacts for installed binaries.
    let compile_time = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(compile_time).exists() {
        compile_time.to_string()
    } else {
        "artifacts".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_valid() {
        SystemConfig::paper().validate().unwrap();
    }

    #[test]
    fn paper_point_matches_table_ii_geometry() {
        let c = IfaceConfig::paper_50mhz();
        assert_eq!(c.pixel_clock_hz, 50.0e6);
        // 4 MiB image buffer holds a full 4 MPixel 8bpp frame.
        assert!(c.image_buffer_words * 4 >= 4 * 1024 * 1024);
    }

    #[test]
    fn rejects_bad_clock() {
        let mut c = IfaceConfig::paper_50mhz();
        c.pixel_clock_hz = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_zero_fifo() {
        let mut c = IfaceConfig::paper_50mhz();
        c.pixel_fifo_depth = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn myriad2_matches_datasheet_envelope() {
        let v = VpuConfig::myriad2();
        assert_eq!(v.n_shaves, 12);
        assert_eq!(v.shave_clock_hz, 600.0e6);
        assert_eq!(v.cmx_bytes, 2 * 1024 * 1024);
    }

    #[test]
    fn dram_copy_rate_reproduces_42ms_per_mpixel() {
        let v = VpuConfig::myriad2();
        let t = (1024.0 * 1024.0) / v.dram_copy_mpx_per_s;
        assert!((t - 0.042).abs() < 0.001, "copy time {t}");
    }

    #[test]
    fn resolved_config_precedence_cli_over_env_over_default() {
        let env = |k: &str| match k {
            "SPACECODESIGN_BACKEND" => Some("simd".to_string()),
            "SPACECODESIGN_VPUS" => Some("4".to_string()),
            _ => None,
        };
        let cli = CliOverrides {
            backend: Some(crate::KernelBackend::Reference),
            ..Default::default()
        };
        let rc = ResolvedConfig::resolve_with(&cli, env);
        assert_eq!(rc.backend.value, crate::KernelBackend::Reference);
        assert_eq!(rc.backend.source, SettingSource::Cli, "CLI beats env");
        assert_eq!(rc.vpus.value, 4);
        assert_eq!(rc.vpus.source, SettingSource::Env, "env beats default");
        assert_eq!(rc.workers.value, None);
        assert_eq!(rc.workers.source, SettingSource::Default);
        assert!((rc.fault_rate.value - 0.02).abs() < 1e-12);
        assert!(rc.fault_config().is_none(), "no seed, no injection");
    }

    #[test]
    fn resolved_config_clamps_env_vpus_and_builds_fault_plans() {
        let env = |k: &str| match k {
            "SPACECODESIGN_VPUS" => Some("999".to_string()),
            "SPACECODESIGN_FAULT_SEED" => Some("17".to_string()),
            "SPACECODESIGN_FAULT_RATE" => Some("0.3".to_string()),
            _ => None,
        };
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), env);
        assert_eq!(rc.vpus.value, crate::coordinator::system::MAX_VPUS);
        let fc = rc.fault_config().unwrap();
        assert_eq!(fc.seed, 17);
        assert!((fc.frame_rate - 0.3).abs() < 1e-12);
        assert!(rc.fault_plan().is_some());
    }

    #[test]
    fn resolved_config_strategy_knob_resolves_and_lands_in_fault_config() {
        use crate::recovery::Strategy;
        // Default: the PR 4 resend baseline, memory domains inert.
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), |k| {
            (k == "SPACECODESIGN_FAULT_SEED").then(|| "9".to_string())
        });
        assert_eq!(rc.fault_strategy.value, Strategy::Resend);
        assert_eq!(rc.fault_strategy.source, SettingSource::Default);
        let fc = rc.fault_config().unwrap();
        assert_eq!(fc.strategy, Strategy::Resend);
        assert_eq!(fc.memory_rate, 0.0, "resolution never arms memory domains");
        assert!(rc.summary().contains("strategy resend"), "{}", rc.summary());
        // Env knob, including a scrub period.
        let env = |k: &str| match k {
            "SPACECODESIGN_FAULT_SEED" => Some("9".to_string()),
            "SPACECODESIGN_FAULT_STRATEGY" => Some("scrub:4".to_string()),
            _ => None,
        };
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), env);
        assert_eq!(
            rc.fault_strategy.value,
            Strategy::Scrub { period: 4, weights_period: 4 }
        );
        assert_eq!(rc.fault_strategy.source, SettingSource::Env);
        // CLI beats env.
        let cli = CliOverrides {
            fault_strategy: Some(Strategy::Fec),
            ..Default::default()
        };
        let rc = ResolvedConfig::resolve_with(&cli, env);
        assert_eq!(rc.fault_strategy.value, Strategy::Fec);
        assert_eq!(rc.fault_strategy.source, SettingSource::Cli);
        assert_eq!(rc.fault_config().unwrap().strategy, Strategy::Fec);
        // An unparseable env value falls back to the default.
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), |k| {
            (k == "SPACECODESIGN_FAULT_STRATEGY").then(|| "retry".to_string())
        });
        assert_eq!(rc.fault_strategy.value, Strategy::Resend);
        assert_eq!(rc.fault_strategy.source, SettingSource::Default);
    }

    #[test]
    fn resolved_config_precision_precedence_and_summary() {
        use crate::Precision;
        // Default: f32, the pinned PR 9 behavior.
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), |_| None);
        assert_eq!(rc.precision.value, Precision::F32);
        assert_eq!(rc.precision.source, SettingSource::Default);
        // Env knob (tolerant spelling).
        let env = |k: &str| {
            (k == "SPACECODESIGN_PRECISION").then(|| "INT8".to_string())
        };
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), env);
        assert_eq!(rc.precision.value, Precision::Int8);
        assert_eq!(rc.precision.source, SettingSource::Env);
        assert!(rc.summary().contains("precision int8 [env]"), "{}", rc.summary());
        // CLI beats env.
        let cli = CliOverrides {
            precision: Some(Precision::F32),
            ..Default::default()
        };
        let rc = ResolvedConfig::resolve_with(&cli, env);
        assert_eq!(rc.precision.value, Precision::F32);
        assert_eq!(rc.precision.source, SettingSource::Cli);
        // An unparseable env value falls back to the default.
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), |k| {
            (k == "SPACECODESIGN_PRECISION").then(|| "fp4".to_string())
        });
        assert_eq!(rc.precision.value, Precision::F32);
        assert_eq!(rc.precision.source, SettingSource::Default);
    }

    #[test]
    fn resolved_config_summary_names_every_source() {
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), |_| None);
        let s = rc.summary();
        assert!(s.contains("backend optimized [default]"), "{s}");
        assert!(s.contains("precision f32 [default]"), "{s}");
        assert!(s.contains("workers auto [default]"), "{s}");
        assert!(s.contains("vpus 1 [default]"), "{s}");
        assert!(s.contains("fleet off [default]"), "{s}");
        assert!(s.contains("faults off [default]"), "{s}");
    }

    #[test]
    fn fleet_spec_round_trips_through_display() {
        for s in [
            "2x600MHz:12,1x300MHz:4",
            "1x600MHz:12",
            "3x150MHz:2:64MB",
            "1x600.5MHz:12",
            "2x600MHz:12@0.001",
            "3x150MHz:2:64MB@0.001",
            "1x600MHz:12@0.5,1x300MHz:4",
        ] {
            let spec = FleetSpec::parse(s).unwrap();
            assert_eq!(spec.to_string(), s, "canonical form of {s}");
            assert_eq!(FleetSpec::parse(&spec.to_string()).unwrap(), spec);
        }
        // Tolerant spellings normalize to the canonical form.
        let spec = FleetSpec::parse(" 2X600mhz:12 , 1x300:4:512mb ").unwrap();
        assert_eq!(spec.to_string(), "2x600MHz:12,1x300MHz:4");
        assert_eq!(spec.n_nodes(), 3);
        // Scientific-notation rates parse; Display renders them in
        // Rust's canonical f64 form.
        let spec = FleetSpec::parse("2x600MHz:12@1e-5").unwrap();
        assert_eq!(spec.groups[0].upset_rate, Some(1e-5));
        assert_eq!(
            FleetSpec::parse(&spec.to_string()).unwrap(),
            spec,
            "rendered rate re-parses to the same spec"
        );
    }

    #[test]
    fn fleet_node_upset_rates_index_by_topology_position() {
        let spec = FleetSpec::parse("2x600MHz:12@1e-4,1x300MHz:4").unwrap();
        assert_eq!(
            spec.node_upset_rates(),
            vec![Some(1e-4), Some(1e-4), None],
            "per-group rate repeats per node; no suffix inherits"
        );
        let plain = FleetSpec::parse("2x600MHz:12").unwrap();
        assert_eq!(plain.node_upset_rates(), vec![None, None]);
    }

    #[test]
    fn fleet_spec_rejects_malformed_and_implausible() {
        for s in [
            "",                  // empty
            "2x600MHz",          // missing SHAVEs
            "600MHz:12",         // missing count
            "0x600MHz:12",       // zero nodes
            "1x600MHz:0",        // zero SHAVEs
            "1x600MHz:65",       // too many SHAVEs
            "1x9000MHz:12",      // clock out of range
            "1x600MHz:12:4MB",   // DRAM too small
            "1x600MHz:12:4:4",   // trailing fields
            "1xfastMHz:12",      // junk clock
            "33x600MHz:12",      // exceeds MAX_VPUS
            "1x600MHz:12@",      // empty upset rate
            "1x600MHz:12@hot",   // junk upset rate
            "1x600MHz:12@1.5",   // rate above 1
            "1x600MHz:12@-0.1",  // negative rate
        ] {
            assert!(FleetSpec::parse(s).is_err(), "should reject {s:?}");
        }
    }

    #[test]
    fn fleet_node_vpu_scales_clock_shaves_and_copy_rates() {
        let base = VpuConfig::myriad2();
        let spec = FleetSpec::parse("1x600MHz:12,1x300MHz:4:256MB").unwrap();
        // Node 0 is a plain Myriad2: bitwise-identical config, so the
        // homogeneous-fleet compatibility pin holds by construction.
        let n0 = spec.node_vpu(0, &base);
        assert_eq!(n0.n_shaves, base.n_shaves);
        assert_eq!(n0.shave_clock_hz, base.shave_clock_hz);
        assert_eq!(n0.dram_copy_mpx_per_s, base.dram_copy_mpx_per_s);
        assert_eq!(n0.dram_bytes, base.dram_bytes);
        // Node 1 is half-clock, 4 SHAVEs, 256 MB; DRAM copy/DMA rates
        // halve with the clock.
        let n1 = spec.node_vpu(1, &base);
        assert_eq!(n1.n_shaves, 4);
        assert_eq!(n1.shave_clock_hz, 300.0e6);
        assert_eq!(n1.dram_bytes, 256 * 1024 * 1024);
        assert!((n1.dram_copy_mpx_per_s - base.dram_copy_mpx_per_s * 0.5).abs() < 1.0);
        assert!((n1.dma_bytes_per_s - base.dma_bytes_per_s * 0.5).abs() < 1.0);
        n1.validate().unwrap();
        // Beyond the spec: base part unchanged.
        assert_eq!(spec.node_vpu(7, &base).n_shaves, base.n_shaves);
    }

    #[test]
    fn fleet_precedence_cli_over_env_and_vpus_flag_beats_env_fleet() {
        let env = |k: &str| match k {
            "SPACECODESIGN_FLEET" => Some("2x600MHz:12".to_string()),
            "SPACECODESIGN_VPUS" => Some("7".to_string()),
            _ => None,
        };
        // Env fleet wins over env vpus and derives the topology size.
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), env);
        assert_eq!(rc.fleet.source, SettingSource::Env);
        assert_eq!(rc.vpus.value, 2, "vpus derived from the fleet");
        assert_eq!(rc.vpus.source, SettingSource::Env);
        // CLI fleet beats env fleet.
        let cli = CliOverrides {
            fleet: Some(FleetSpec::parse("1x300MHz:4").unwrap()),
            ..Default::default()
        };
        let rc = ResolvedConfig::resolve_with(&cli, env);
        assert_eq!(rc.fleet.source, SettingSource::Cli);
        assert_eq!(rc.vpus.value, 1);
        assert!(rc.summary().contains("fleet 1x300MHz:4 [cli]"), "{}", rc.summary());
        // An explicit --vpus flag beats the ambient env fleet: the
        // fleet resolves away and the topology stays homogeneous.
        let cli = CliOverrides { vpus: Some(3), ..Default::default() };
        let rc = ResolvedConfig::resolve_with(&cli, env);
        assert_eq!(rc.fleet.value, None);
        assert_eq!(rc.vpus.value, 3);
        assert_eq!(rc.vpus.source, SettingSource::Cli);
        // Unparseable env fleet is ignored like other env knobs.
        let rc = ResolvedConfig::resolve_with(&CliOverrides::default(), |k| {
            (k == "SPACECODESIGN_FLEET").then(|| "garbage".to_string())
        });
        assert_eq!(rc.fleet.value, None);
        assert_eq!(rc.vpus.value, 1);
    }
}
