//! System configuration: the knobs of the testbed in paper §II-§IV.
//!
//! Defaults reproduce the paper's evaluated operating point:
//! CIF/LCD @ 50 MHz, 12 SHAVEs @ 600 MHz, 2 LEONs, XCKU060 framing FPGA.

use crate::error::{Error, Result};

/// Clock + sizing for one pixel interface (CIF or LCD).
#[derive(Clone, Copy, Debug)]
pub struct IfaceConfig {
    /// Pixel clock in Hz; the paper validates up to 50 MHz full-frame,
    /// 100 MHz (CIF) / 90 MHz (LCD) with reduced buffers.
    pub pixel_clock_hz: f64,
    /// Pixel FIFO depth (pixels) between FSM and Tx/Rx.
    pub pixel_fifo_depth: usize,
    /// Image buffer capacity in 32-bit words (BRAM-backed).
    pub image_buffer_words: usize,
    /// Horizontal blanking (porch) overhead per line, in pixel clocks.
    /// Calibrated so a 2048x2048@8bpp frame takes ~85 ms at 50 MHz
    /// (paper Table II).
    pub porch_cycles_per_line: usize,
}

impl IfaceConfig {
    /// Paper operating point: 50 MHz, full-frame buffers.
    pub fn paper_50mhz() -> IfaceConfig {
        IfaceConfig {
            pixel_clock_hz: 50.0e6,
            pixel_fifo_depth: 1024,
            // 1Mi words = 4 MiB: buffers a 4 MPixel 8bpp or 2 MPixel 16bpp
            // frame (paper: "due to the FPGA memory resources, we
            // transmitted ... 16-bit frames with up to 1024x1024 size").
            image_buffer_words: 1 << 20,
            porch_cycles_per_line: 27,
        }
    }

    /// Reduced-buffer high-frequency point (paper: CIF@100/LCD@90 MHz with
    /// frames up to 64x64 @16bpp).
    pub fn reduced_100mhz(pixel_clock_hz: f64) -> IfaceConfig {
        IfaceConfig {
            pixel_clock_hz,
            pixel_fifo_depth: 256,
            image_buffer_words: 2048, // 8 KiB
            porch_cycles_per_line: 27,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if !(1.0e6..=200.0e6).contains(&self.pixel_clock_hz) {
            return Err(Error::Config(format!(
                "pixel clock {} Hz out of range",
                self.pixel_clock_hz
            )));
        }
        if self.pixel_fifo_depth == 0 || self.image_buffer_words == 0 {
            return Err(Error::Config("zero-sized fifo/buffer".into()));
        }
        Ok(())
    }
}

/// Myriad2 VPU model parameters (paper §II/§III-B + Myriad2 datasheet).
#[derive(Clone, Copy, Debug)]
pub struct VpuConfig {
    /// SHAVE vector cores: "the 12 SHAVE cores (VLIW & SIMD, 600MHz)".
    pub n_shaves: usize,
    pub shave_clock_hz: f64,
    /// General-purpose LEON cores (LEON4: one for I/O, one for compute
    /// management in Masked mode).
    pub n_leons: usize,
    pub leon_clock_hz: f64,
    /// CMX scratchpad (SPM) capacity.
    pub cmx_bytes: usize,
    /// DRAM->DRAM buffered-copy rate for Masked-mode double buffering.
    /// Calibrated from the paper: "copying an 1MPixel frame requires
    /// ~42ms" => 25 Mpixel/s (DESIGN.md §4).
    pub dram_copy_mpx_per_s: f64,
    /// DMA engine bandwidth DRAM<->CMX (bytes/s).
    pub dma_bytes_per_s: f64,
}

impl VpuConfig {
    pub fn myriad2() -> VpuConfig {
        VpuConfig {
            n_shaves: 12,
            shave_clock_hz: 600.0e6,
            n_leons: 2,
            leon_clock_hz: 230.0e6, // LEON4 OS/RT clock on Myriad2
            cmx_bytes: 2 * 1024 * 1024,
            dram_copy_mpx_per_s: 25.0e6,
            dma_bytes_per_s: 1.5e9,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_shaves == 0 || self.n_leons == 0 {
            return Err(Error::Config("VPU needs cores".into()));
        }
        if self.cmx_bytes < 64 * 1024 {
            return Err(Error::Config("CMX implausibly small".into()));
        }
        Ok(())
    }
}

/// Whole-testbed configuration.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub cif: IfaceConfig,
    pub lcd: IfaceConfig,
    pub vpu: VpuConfig,
    /// Directory holding AOT artifacts + manifest.json.
    pub artifacts_dir: String,
    /// Validate outputs against host groundtruth after each frame.
    pub validate: bool,
}

impl SystemConfig {
    /// The paper's evaluated configuration (Table II).
    pub fn paper() -> SystemConfig {
        SystemConfig {
            cif: IfaceConfig::paper_50mhz(),
            lcd: IfaceConfig::paper_50mhz(),
            vpu: VpuConfig::myriad2(),
            artifacts_dir: default_artifacts_dir(),
            validate: true,
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.cif.validate()?;
        self.lcd.validate()?;
        self.vpu.validate()
    }
}

/// Resolve the artifacts directory: $SPACECODESIGN_ARTIFACTS, else
/// ./artifacts relative to the crate root (where `make artifacts` puts it).
pub fn default_artifacts_dir() -> String {
    if let Ok(dir) = std::env::var("SPACECODESIGN_ARTIFACTS") {
        return dir;
    }
    // Crate root = CARGO_MANIFEST_DIR at compile time (tests, benches),
    // falling back to ./artifacts for installed binaries.
    let compile_time = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    if std::path::Path::new(compile_time).exists() {
        compile_time.to_string()
    } else {
        "artifacts".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_config_is_valid() {
        SystemConfig::paper().validate().unwrap();
    }

    #[test]
    fn paper_point_matches_table_ii_geometry() {
        let c = IfaceConfig::paper_50mhz();
        assert_eq!(c.pixel_clock_hz, 50.0e6);
        // 4 MiB image buffer holds a full 4 MPixel 8bpp frame.
        assert!(c.image_buffer_words * 4 >= 4 * 1024 * 1024);
    }

    #[test]
    fn rejects_bad_clock() {
        let mut c = IfaceConfig::paper_50mhz();
        c.pixel_clock_hz = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_zero_fifo() {
        let mut c = IfaceConfig::paper_50mhz();
        c.pixel_fifo_depth = 0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn myriad2_matches_datasheet_envelope() {
        let v = VpuConfig::myriad2();
        assert_eq!(v.n_shaves, 12);
        assert_eq!(v.shave_clock_hz, 600.0e6);
        assert_eq!(v.cmx_bytes, 2 * 1024 * 1024);
    }

    #[test]
    fn dram_copy_rate_reproduces_42ms_per_mpixel() {
        let v = VpuConfig::myriad2();
        let t = (1024.0 * 1024.0) / v.dram_copy_mpx_per_s;
        assert!((t - 0.042).abs() < 0.001, "copy time {t}");
    }
}
