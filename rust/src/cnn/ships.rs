//! Synthetic ship/sea chip generator — the Rust mirror of
//! `python/compile/datasets.py::ship_chips` (same visual structure:
//! correlated bluish swell background, bright tapered hull with deck
//! stripe and wake).
//!
//! The random sequences differ from numpy's, so chips are not bit-equal
//! to the training set — deliberately: classifying Rust-generated chips
//! with the Python-trained weights is a *generalization* check, not a
//! memorization check (see `rust/tests/integration_cnn.rs`).

use crate::cnn::layers::FeatureMap;
use crate::util::rng::Rng;

/// One labelled chip: (size x size x 3) RGB in [0,1] + ship flag.
pub struct Chip {
    pub fm: FeatureMap,
    pub has_ship: bool,
}

fn sea_background(rng: &mut Rng, size: usize) -> Vec<f32> {
    let base = 0.25 + 0.1 * rng.next_f32();
    // Three swell components.
    let mut comps = Vec::new();
    for _ in 0..3 {
        let fx = rng.range_f64(2.0, 9.0) as f32;
        let fy = rng.range_f64(2.0, 9.0) as f32;
        let p0 = rng.range_f64(0.0, std::f64::consts::TAU) as f32;
        let p1 = rng.range_f64(0.0, std::f64::consts::TAU) as f32;
        comps.push((fx, fy, p0, p1));
    }
    let mut out = vec![0f32; size * size * 3];
    let inv = 1.0 / (size - 1).max(1) as f32;
    for y in 0..size {
        let fy = y as f32 * inv;
        for x in 0..size {
            let fxn = x as f32 * inv;
            let mut swell = 0f32;
            for &(fx, fyc, p0, p1) in &comps {
                swell += (std::f32::consts::TAU * fx * fxn + p0).sin()
                    * (std::f32::consts::TAU * fyc * fy + p1).cos();
            }
            let lum =
                base + 0.02 * swell + 0.015 * rng.normal() as f32;
            let idx = (y * size + x) * 3;
            out[idx] = (lum * 0.55).clamp(0.0, 1.0);
            out[idx + 1] = (lum * 0.85).clamp(0.0, 1.0);
            out[idx + 2] = lum.clamp(0.0, 1.0);
        }
    }
    out
}

fn paint_ship(rng: &mut Rng, data: &mut [f32], size: usize) {
    let s = size as f32;
    let cy = rng.range_f64(0.3, 0.7) as f32 * s;
    let cx = rng.range_f64(0.3, 0.7) as f32 * s;
    let length = rng.range_f64(0.18, 0.42) as f32 * s;
    let width = length * rng.range_f64(0.22, 0.38) as f32;
    let theta = rng.range_f64(0.0, std::f64::consts::PI) as f32;
    let (st, ct) = theta.sin_cos();
    let bright = rng.range_f64(0.55, 0.9) as f32;
    for y in 0..size {
        for x in 0..size {
            let dx = x as f32 - cx;
            let dy = y as f32 - cy;
            let u = dx * ct + dy * st;
            let v = -dx * st + dy * ct;
            let taper = (1.0 - u.max(0.0) / (0.6 * length)).clamp(0.25, 1.0);
            let idx = (y * size + x) * 3;
            if u.abs() < length / 2.0 && v.abs() < (width / 2.0) * taper {
                data[idx] = bright;
                data[idx + 1] = bright * 0.97;
                data[idx + 2] = bright * 0.92;
                if v.abs() < width * 0.08 {
                    data[idx] *= 0.6; // deck stripe
                }
            } else if u < -length / 2.0
                && u > -length * 1.6
                && v.abs() < width * 0.4 * (1.0 + (-u - length / 2.0) / length)
            {
                // Wake behind the stern.
                let wobble = 0.5 + 0.5 * (u * 0.9).sin();
                for c in 0..3 {
                    data[idx + c] = (data[idx + c] + 0.12 * wobble).min(1.0);
                }
            }
        }
    }
}

/// Generate `n` chips at `size` px, ~50 % with ships.
pub fn ship_chips(n: usize, size: usize, seed: u64) -> Vec<Chip> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut data = sea_background(&mut rng, size);
            let has_ship = rng.bool(0.5);
            if has_ship {
                paint_ship(&mut rng, &mut data, size);
            }
            Chip {
                fm: FeatureMap::from_data(size, size, 3, data).unwrap(),
                has_ship,
            }
        })
        .collect()
}

/// Tile `grid x grid` chips into one RGB satellite frame; returns the
/// frame as three planes worth of row-major RGB f32 and the labels in
/// row-major patch order (the paper's LEON splitter order).
pub fn ship_frame(grid: usize, patch: usize, seed: u64) -> (Vec<f32>, Vec<bool>) {
    let chips = ship_chips(grid * grid, patch, seed);
    let side = grid * patch;
    let mut frame = vec![0f32; side * side * 3];
    let mut labels = Vec::with_capacity(grid * grid);
    for (i, chip) in chips.iter().enumerate() {
        let gy = i / grid;
        let gx = i % grid;
        for y in 0..patch {
            for x in 0..patch {
                for c in 0..3 {
                    frame[(((gy * patch + y) * side) + gx * patch + x) * 3 + c] =
                        chip.fm.data[(y * patch + x) * 3 + c];
                }
            }
        }
        labels.push(chip.has_ship);
    }
    (frame, labels)
}

/// Copy patch `(gy, gx)` of an interleaved-RGB `side x side` frame into
/// `chip` (which must be `patch x patch x 3`). This is the LEON
/// splitter: the host groundtruth (`coordinator::host`) and the native
/// artifact engine (`runtime::native`) both extract through this one
/// function so their per-patch inputs are bit-identical.
pub fn extract_chip_into(
    frame: &[f32],
    side: usize,
    patch: usize,
    gy: usize,
    gx: usize,
    chip: &mut FeatureMap,
) {
    debug_assert_eq!(chip.data.len(), patch * patch * 3);
    debug_assert_eq!(frame.len(), side * side * 3);
    for y in 0..patch {
        let src = (((gy * patch + y) * side) + gx * patch) * 3;
        let dst = y * patch * 3;
        chip.data[dst..dst + patch * 3].copy_from_slice(&frame[src..src + patch * 3]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a = ship_chips(4, 64, 42);
        let b = ship_chips(4, 64, 42);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.has_ship, y.has_ship);
            assert_eq!(x.fm.data, y.fm.data);
        }
    }

    #[test]
    fn chips_in_unit_range() {
        for chip in ship_chips(8, 64, 1) {
            assert!(chip.fm.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn labels_roughly_balanced() {
        let chips = ship_chips(300, 32, 2);
        let ships = chips.iter().filter(|c| c.has_ship).count();
        assert!((90..210).contains(&ships), "{ships}/300");
    }

    #[test]
    fn ships_are_brighter_than_sea() {
        let chips = ship_chips(200, 64, 3);
        let max_of = |c: &Chip| {
            c.fm.data.iter().cloned().fold(0f32, f32::max)
        };
        let ship_avg: f32 = chips
            .iter()
            .filter(|c| c.has_ship)
            .map(max_of)
            .sum::<f32>()
            / chips.iter().filter(|c| c.has_ship).count() as f32;
        let sea_avg: f32 = chips
            .iter()
            .filter(|c| !c.has_ship)
            .map(max_of)
            .sum::<f32>()
            / chips.iter().filter(|c| !c.has_ship).count() as f32;
        assert!(
            ship_avg > sea_avg + 0.1,
            "ship {ship_avg} vs sea {sea_avg}"
        );
    }

    #[test]
    fn extract_chip_inverts_frame_tiling() {
        let (frame, _) = ship_frame(2, 64, 13);
        let chips = ship_chips(4, 64, 13);
        let mut got = FeatureMap::new(64, 64, 3);
        for (i, chip) in chips.iter().enumerate() {
            extract_chip_into(&frame, 128, 64, i / 2, i % 2, &mut got);
            assert_eq!(got.data, chip.fm.data, "patch {i}");
        }
    }

    #[test]
    fn frame_tiles_in_label_order() {
        let (frame, labels) = ship_frame(2, 64, 7);
        assert_eq!(frame.len(), 128 * 128 * 3);
        assert_eq!(labels.len(), 4);
        let chips = ship_chips(4, 64, 7);
        // Top-left patch == chip 0.
        for y in 0..64 {
            for x in 0..64 {
                for c in 0..3 {
                    assert_eq!(
                        frame[((y * 128) + x) * 3 + c],
                        chips[0].fm.data[(y * 64 + x) * 3 + c]
                    );
                }
            }
        }
    }
}
