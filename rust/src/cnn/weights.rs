//! Loader for `artifacts/cnn_weights.bin` (format defined in
//! `python/compile/train_cnn.py::save_weights_bin`):
//! magic "CNNW" | u32 n | per tensor: u32 name_len, name, u32 ndim,
//! u32 dims..., f32 data (all little-endian). Values are fp16-quantized
//! at export, matching what the AOT artifact bakes in.

use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// One tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.dims.iter().product()
    }
}

/// The named parameter set of the 6-layer ship CNN.
#[derive(Clone, Debug, Default)]
pub struct Weights {
    pub tensors: BTreeMap<String, Tensor>,
}

impl Weights {
    pub fn from_bytes(bytes: &[u8]) -> Result<Weights> {
        let err = |msg: String| Error::ArtifactParse {
            path: "<weights bytes>".into(),
            msg,
        };
        if bytes.len() < 8 || &bytes[..4] != b"CNNW" {
            return Err(err("bad magic".into()));
        }
        let n = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let mut off = 8;
        let mut take = |len: usize| -> Result<&[u8]> {
            if off + len > bytes.len() {
                return Err(Error::ArtifactParse {
                    path: "<weights bytes>".into(),
                    msg: format!("truncated at offset {off}"),
                });
            }
            let s = &bytes[off..off + len];
            off += len;
            Ok(s)
        };
        let mut tensors = BTreeMap::new();
        for _ in 0..n {
            let name_len =
                u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            if name_len > 256 {
                return Err(err(format!("implausible name length {name_len}")));
            }
            let name = String::from_utf8(take(name_len)?.to_vec())
                .map_err(|e| err(e.to_string()))?;
            let ndim = u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize;
            if ndim > 8 {
                return Err(err(format!("implausible ndim {ndim}")));
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(u32::from_le_bytes(take(4)?.try_into().unwrap()) as usize);
            }
            let numel: usize = dims.iter().product();
            if numel > 10_000_000 {
                return Err(err(format!("implausible tensor size {numel}")));
            }
            let raw = take(numel * 4)?;
            let data = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            tensors.insert(name, Tensor { dims, data });
        }
        if off != bytes.len() {
            return Err(err(format!(
                "{} trailing bytes after {n} tensors",
                bytes.len() - off
            )));
        }
        Ok(Weights { tensors })
    }

    pub fn load<P: AsRef<Path>>(path: P) -> Result<Weights> {
        let bytes = std::fs::read(&path).map_err(|e| Error::ArtifactParse {
            path: path.as_ref().display().to_string(),
            msg: e.to_string(),
        })?;
        Weights::from_bytes(&bytes)
    }

    /// Random weights with the trained 6-layer architecture's exact
    /// shapes — lets benches and equivalence tests exercise the full
    /// forward pass without `make artifacts`. Deterministic per seed.
    pub fn synthetic_ship(seed: u64) -> Weights {
        let dims: [(&str, Vec<usize>); 12] = [
            ("conv0_w", vec![3, 3, 3, 8]),
            ("conv0_b", vec![8]),
            ("conv1_w", vec![3, 3, 8, 16]),
            ("conv1_b", vec![16]),
            ("conv2_w", vec![3, 3, 16, 32]),
            ("conv2_b", vec![32]),
            ("conv3_w", vec![3, 3, 32, 32]),
            ("conv3_b", vec![32]),
            ("fc0_w", vec![2048, 57]),
            ("fc0_b", vec![57]),
            ("fc1_w", vec![57, 2]),
            ("fc1_b", vec![2]),
        ];
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut tensors = BTreeMap::new();
        for (name, dims) in dims {
            let numel: usize = dims.iter().product();
            let data: Vec<f32> =
                (0..numel).map(|_| (rng.next_f32() - 0.5) * 0.2).collect();
            tensors.insert(name.to_string(), Tensor { dims, data });
        }
        Weights { tensors }
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| Error::ArtifactParse {
                path: "<weights>".into(),
                msg: format!("missing tensor '{name}'"),
            })
    }

    pub fn param_count(&self) -> usize {
        self.tensors.values().map(Tensor::numel).sum()
    }

    /// Sanity-check the expected 6-layer architecture.
    pub fn validate_architecture(&self) -> Result<()> {
        let expected: [(&str, &[usize]); 12] = [
            ("conv0_w", &[3, 3, 3, 8]),
            ("conv0_b", &[8]),
            ("conv1_w", &[3, 3, 8, 16]),
            ("conv1_b", &[16]),
            ("conv2_w", &[3, 3, 16, 32]),
            ("conv2_b", &[32]),
            ("conv3_w", &[3, 3, 32, 32]),
            ("conv3_b", &[32]),
            ("fc0_w", &[2048, 57]),
            ("fc0_b", &[57]),
            ("fc1_w", &[57, 2]),
            ("fc1_b", &[2]),
        ];
        for (name, dims) in expected {
            let t = self.get(name)?;
            if t.dims != dims {
                return Err(Error::ArtifactParse {
                    path: "<weights>".into(),
                    msg: format!("{name}: dims {:?}, expected {:?}", t.dims, dims),
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_weights_bytes() -> Vec<u8> {
        // Two tensors: "a" = [2] f32, "b" = [1, 2] f32.
        let mut out = Vec::new();
        out.extend_from_slice(b"CNNW");
        out.extend_from_slice(&2u32.to_le_bytes());
        // "a"
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(b"a");
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&1.5f32.to_le_bytes());
        out.extend_from_slice(&(-2.0f32).to_le_bytes());
        // "b"
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(b"b");
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&1u32.to_le_bytes());
        out.extend_from_slice(&2u32.to_le_bytes());
        out.extend_from_slice(&0.0f32.to_le_bytes());
        out.extend_from_slice(&7.0f32.to_le_bytes());
        out
    }

    #[test]
    fn parses_tiny_file() {
        let w = Weights::from_bytes(&tiny_weights_bytes()).unwrap();
        assert_eq!(w.get("a").unwrap().data, vec![1.5, -2.0]);
        assert_eq!(w.get("b").unwrap().dims, vec![1, 2]);
        assert_eq!(w.param_count(), 4);
    }

    #[test]
    fn rejects_truncation_and_trailing() {
        let bytes = tiny_weights_bytes();
        assert!(Weights::from_bytes(&bytes[..bytes.len() - 2]).is_err());
        let mut extra = bytes.clone();
        extra.push(0);
        assert!(Weights::from_bytes(&extra).is_err());
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(Weights::from_bytes(b"XXXX\0\0\0\0").is_err());
    }

    #[test]
    fn missing_tensor_reported() {
        let w = Weights::from_bytes(&tiny_weights_bytes()).unwrap();
        assert!(w.get("nope").is_err());
    }

    #[test]
    fn loads_trained_weights_if_built() {
        let dir = crate::config::default_artifacts_dir();
        let path = format!("{dir}/cnn_weights.bin");
        if std::path::Path::new(&path).exists() {
            let w = Weights::load(&path).unwrap();
            w.validate_architecture().unwrap();
            // Paper: "6-layer network (132K parameters)".
            assert_eq!(w.param_count(), 132_189);
            // fp16 quantization: every value exactly representable.
            for t in w.tensors.values() {
                for &v in &t.data {
                    assert!(v.is_finite());
                }
            }
        }
    }
}
