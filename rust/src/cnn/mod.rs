//! CNN ship-detection substrate (paper §III-C, benchmark 4).
//!
//! The scalar fp32 inference engine ([`layers`]) is the LEON-baseline
//! implementation (the paper notes LEON lacks fp16 and would run the
//! fp32 model) *and* the host groundtruth for validating the AOT
//! artifact's logits. [`weights`] loads the trained parameters exported
//! by `python/compile/train_cnn.py`; [`ships`] generates synthetic
//! ship/sea chips matching the training distribution.

pub mod layers;
pub mod ships;
pub mod weights;

pub use layers::cnn_forward;
pub use weights::Weights;
