//! CNN ship-detection substrate (paper §III-C, benchmark 4).
//!
//! The scalar fp32 inference engine ([`layers`]) is the LEON-baseline
//! implementation (the paper notes LEON lacks fp16 and would run the
//! fp32 model) *and* the host groundtruth for validating the AOT
//! artifact's logits. [`weights`] loads the trained parameters exported
//! by `python/compile/train_cnn.py`; [`ships`] generates synthetic
//! ship/sea chips matching the training distribution.

//! [`fast`] is the `KernelBackend::Optimized` twin of [`layers`]
//! (repacked weights, row-pointer pooling, ping-pong buffers, row
//! fan-out) and [`simd`] the `KernelBackend::Simd` twin (eight
//! output-channel lanes over the unpacked HWIO layout, bit-identical
//! to the reference); [`forward`]/[`classify`] dispatch between the
//! tiers. [`quant`] is the `Precision::Int8` path: per-layer symmetric
//! quantization with its own three backend tiers, bit-reproducible
//! across all of them by integer construction.

pub mod fast;
pub mod layers;
pub mod quant;
pub mod ships;
pub mod simd;
pub mod weights;

pub use layers::cnn_forward;
pub use quant::QuantizedWeights;
pub use weights::Weights;

use crate::error::Result;
use crate::KernelBackend;

/// Backend-dispatched full 6-layer forward pass.
pub fn forward(
    backend: KernelBackend,
    weights: &Weights,
    chip: &layers::FeatureMap,
) -> Result<[f32; 2]> {
    match backend {
        KernelBackend::Reference => layers::cnn_forward(weights, chip),
        KernelBackend::Optimized => fast::cnn_forward_opt(weights, chip),
        KernelBackend::Simd => simd::cnn_forward_simd(weights, chip),
    }
}

/// Backend-dispatched argmax classification.
pub fn classify(
    backend: KernelBackend,
    weights: &Weights,
    chip: &layers::FeatureMap,
) -> Result<usize> {
    let l = forward(backend, weights, chip)?;
    Ok(usize::from(l[1] > l[0]))
}
