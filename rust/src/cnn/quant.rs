//! Int8 quantized CNN inference (`Precision::Int8`) — per-layer
//! symmetric post-training quantization of the 6-layer ship CNN.
//!
//! Quantization scheme (the Myriad2's real SHAVE advantage is
//! low-precision arithmetic — arXiv 2506.12970):
//!
//! * **weights**: per-layer symmetric i8, `q = round(w / s_w)` with
//!   `s_w = max|w| / 127` (an all-zero tensor is rejected — a zero
//!   scale cannot be inverted).
//! * **activations**: u8 with zero-point 0 (every activation is
//!   post-ReLU, so the domain is one-sided); the input chip is [0, 1]
//!   RGB quantized at `s = 1/255`. Per-layer output scales are
//!   calibrated with one scalar-reference forward pass over a small
//!   deterministic ship-chip set ([`CALIB_SEED`]), recording each
//!   layer's max activation. Max pool commutes with the (monotonic)
//!   quantizer, so conv output and pool output share one scale.
//! * **accumulators**: i32, initialized from the i32-quantized bias
//!   (scaled at `s_in * s_w`), then a single rounding/saturating
//!   [`requantize`] back to u8 per layer. The worst-case accumulator
//!   (`2048` taps of `255·127`) stays far below `i32::MAX`, so integer
//!   addition is exact and **associative** — every backend tier and
//!   every worker split produces bit-identical results by construction
//!   (stronger than the f32 tiers' order-replay contract).
//!
//! Three kernel tiers mirror the f32 path: a scalar reference, an
//! Optimized tier (tap-major repacked weights + row fan-out via
//! [`crate::util::par`]), and a Simd tier (eight output-channel
//! [`I32x8`] lanes with widening u8×i8 multiply-accumulate, [`U8x8`]
//! lane max pool). The final dense layer dequantizes its i32
//! accumulators to f32 logits so the public signature matches the f32
//! path's `[f32; 2]`.

use crate::cnn::layers::{conv3x3_relu, dense, maxpool2x2, FeatureMap};
use crate::cnn::weights::Weights;
use crate::error::{Error, Result};
use crate::util::lanes::{I32x8, U8x8, LANES};
use crate::util::par;
use crate::util::par::GRAIN_OPS;
use crate::KernelBackend;

/// Seed of the deterministic ship-chip calibration set — fixed so the
/// quantization parameters are a pure function of the f32 weights.
pub const CALIB_SEED: u64 = 0xCA11B;

/// Calibration set size (full 128 px chips; two suffice — the scales
/// only need the activation *magnitude*, not the distribution tails).
pub const CALIB_CHIPS: usize = 2;

/// u8 activation map with zero-point 0: `value ≈ q * scale`.
#[derive(Clone, Debug)]
pub struct QuantMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
}

/// One quantized conv layer (both weight layouts are materialized once
/// at build time: HWIO for the reference/Simd tiers, tap-major for the
/// Optimized tier's contiguous-`ic` scalar loop).
#[derive(Clone, Debug)]
pub struct QuantConv {
    pub cin: usize,
    pub cout: usize,
    /// HWIO i8 taps, same layout as the f32 tensor.
    pub w: Vec<i8>,
    /// Tap-major `(tap, Cout, Cin)` repack (see `cnn::fast`).
    pub packed: Vec<i8>,
    /// Bias quantized at `s_in * s_w`.
    pub bias: Vec<i32>,
    /// Requantize multiplier `s_in * s_w / s_out`.
    pub m: f64,
    /// Weight scale (`f32 weight ≈ q * s_w`).
    pub s_w: f64,
    /// Output activation scale (`f32 activation ≈ q * s_out`).
    pub s_out: f64,
}

/// One quantized dense layer, row-major `(Din, Dout)` weights.
#[derive(Clone, Debug)]
pub struct QuantDense {
    pub din: usize,
    pub dout: usize,
    pub w: Vec<i8>,
    pub bias: Vec<i32>,
    /// fc0: requantize multiplier to the hidden scale. fc1: dequantize
    /// multiplier straight to f32 logits (`s_in * s_w`).
    pub m: f64,
    pub s_w: f64,
}

/// The fully-quantized 6-layer parameter set, built once per weight set
/// by [`QuantizedWeights::from_weights`] and cached by the callers that
/// stream patches (`runtime::native`, `coordinator::host`).
#[derive(Clone, Debug)]
pub struct QuantizedWeights {
    /// Input activation scale (1/255 over the [0, 1] RGB domain).
    pub s_in: f64,
    pub conv: Vec<QuantConv>,
    pub fc0: QuantDense,
    pub fc1: QuantDense,
}

/// Round-and-saturate an i32 accumulator back to a u8 activation:
/// `clamp(round(acc * m), 0, 255)`. ReLU is folded in (a negative
/// accumulator clamps to 0 — the zero-point), and both i32 extremes
/// saturate cleanly. `round` is half-away-from-zero in f64 — exactly
/// reproducible on every platform.
#[inline(always)]
pub fn requantize(acc: i32, m: f64) -> u8 {
    let v = (acc as f64 * m).round();
    if v <= 0.0 {
        0
    } else if v >= 255.0 {
        255
    } else {
        v as u8
    }
}

/// Quantize a [0, 1] f32 chip to u8 at scale 1/255 (values outside the
/// domain saturate).
pub fn quantize_chip(chip: &FeatureMap) -> QuantMap {
    QuantMap {
        h: chip.h,
        w: chip.w,
        c: chip.c,
        data: chip
            .data
            .iter()
            .map(|&v| (v * 255.0).round().clamp(0.0, 255.0) as u8)
            .collect(),
    }
}

/// Dequantize a u8 map back to f32 at `scale` (accuracy tests only —
/// the inference path never leaves the integer domain between layers).
pub fn dequantize(q: &QuantMap, scale: f64) -> FeatureMap {
    FeatureMap {
        h: q.h,
        w: q.w,
        c: q.c,
        data: q.data.iter().map(|&v| (v as f64 * scale) as f32).collect(),
    }
}

/// Symmetric i8 quantization of one tensor; errors on an all-zero (or
/// non-finite) tensor — a zero scale cannot be inverted at requantize.
fn quantize_tensor(name: &str, data: &[f32]) -> Result<(Vec<i8>, f64)> {
    let maxabs = data.iter().fold(0f32, |m, &v| m.max(v.abs()));
    if !(maxabs > 0.0) || !maxabs.is_finite() {
        return Err(Error::ArtifactParse {
            path: "<weights>".into(),
            msg: format!("{name}: cannot quantize (max|w| = {maxabs}, zero scale)"),
        });
    }
    let s = maxabs as f64 / 127.0;
    let q = data
        .iter()
        .map(|&v| (v as f64 / s).round().clamp(-127.0, 127.0) as i8)
        .collect();
    Ok((q, s))
}

fn quantize_bias(b: f32, scale: f64) -> i32 {
    (b as f64 / scale)
        .round()
        .clamp(i32::MIN as f64, i32::MAX as f64) as i32
}

/// Tap-major i8 repack, the integer twin of `cnn::fast::repack_hwio`:
/// `packed[(tap * cout + oc) * cin + ic] = w[(tap * cin + ic) * cout + oc]`.
fn repack_hwio_i8(w: &[i8], cin: usize, cout: usize) -> Vec<i8> {
    debug_assert_eq!(w.len(), 9 * cin * cout);
    let mut packed = vec![0i8; 9 * cout * cin];
    for tap in 0..9 {
        for ic in 0..cin {
            for oc in 0..cout {
                packed[(tap * cout + oc) * cin + ic] = w[(tap * cin + ic) * cout + oc];
            }
        }
    }
    packed
}

impl QuantizedWeights {
    /// Quantize the f32 parameter set: symmetric per-layer weight
    /// scales first (cheap, fails fast on a zero scale), then one
    /// scalar-reference calibration pass over the [`CALIB_SEED`] ship
    /// chips for the activation scales. Backend- and worker-independent
    /// by construction (the calibration always runs the single-threaded
    /// scalar tier).
    pub fn from_weights(weights: &Weights) -> Result<QuantizedWeights> {
        // Weight quantization (fails fast on degenerate tensors).
        let mut conv_q = Vec::with_capacity(4);
        for i in 0..4 {
            let wt = weights.get(&format!("conv{i}_w"))?;
            let (q, s_w) = quantize_tensor(&format!("conv{i}_w"), &wt.data)?;
            conv_q.push((q, s_w));
        }
        let fc0w = weights.get("fc0_w")?;
        let fc1w = weights.get("fc1_w")?;
        let (qf0, s_wf0) = quantize_tensor("fc0_w", &fc0w.data)?;
        let (qf1, s_wf1) = quantize_tensor("fc1_w", &fc1w.data)?;

        // Activation-scale calibration: max |activation| per stage over
        // the deterministic ship set, scalar reference tier.
        let mut maxes = [0f32; 5]; // conv0..conv3 outputs, fc0 hidden
        let fc0b = weights.get("fc0_b")?;
        let hid = *fc0w.dims.last().unwrap();
        for chip in crate::cnn::ships::ship_chips(CALIB_CHIPS, 128, CALIB_SEED) {
            let mut fm = chip.fm;
            for (i, mx) in maxes.iter_mut().take(4).enumerate() {
                let w = weights.get(&format!("conv{i}_w"))?;
                let b = weights.get(&format!("conv{i}_b"))?;
                let cout = *w.dims.last().unwrap();
                fm = conv3x3_relu(&fm, &w.data, &b.data, cout);
                *mx = fm.data.iter().fold(*mx, |m, &v| m.max(v));
                fm = maxpool2x2(&fm);
            }
            let hidden = dense(&fm.data, &fc0w.data, &fc0b.data, hid, true);
            maxes[4] = hidden.iter().fold(maxes[4], |m, &v| m.max(v));
        }
        let s_in0 = 1.0 / 255.0f64;
        // A stage that never activates still needs an invertible scale.
        let act_scale = |mx: f32| if mx > 0.0 { mx as f64 / 255.0 } else { s_in0 };

        let mut conv = Vec::with_capacity(4);
        let mut s_in = s_in0;
        for (i, (q, s_w)) in conv_q.into_iter().enumerate() {
            let wt = weights.get(&format!("conv{i}_w"))?;
            let bt = weights.get(&format!("conv{i}_b"))?;
            let cin = wt.dims[2];
            let cout = *wt.dims.last().unwrap();
            let s_out = act_scale(maxes[i]);
            let bs = s_in * s_w;
            let packed = repack_hwio_i8(&q, cin, cout);
            conv.push(QuantConv {
                cin,
                cout,
                w: q,
                packed,
                bias: bt.data.iter().map(|&b| quantize_bias(b, bs)).collect(),
                m: bs / s_out,
                s_w,
                s_out,
            });
            s_in = s_out;
        }
        let s_h = act_scale(maxes[4]);
        let fc1b = weights.get("fc1_b")?;
        let fc0 = QuantDense {
            din: fc0w.dims[0],
            dout: hid,
            w: qf0,
            bias: fc0b
                .data
                .iter()
                .map(|&b| quantize_bias(b, s_in * s_wf0))
                .collect(),
            m: s_in * s_wf0 / s_h,
            s_w: s_wf0,
        };
        let fc1 = QuantDense {
            din: fc1w.dims[0],
            dout: *fc1w.dims.last().unwrap(),
            w: qf1,
            bias: fc1b
                .data
                .iter()
                .map(|&b| quantize_bias(b, s_h * s_wf1))
                .collect(),
            m: s_h * s_wf1, // dequantize multiplier: logits stay f32
            s_w: s_wf1,
        };
        Ok(QuantizedWeights {
            s_in: s_in0,
            conv,
            fc0,
            fc1,
        })
    }
}

/// Scalar reference int8 conv: same clamped-window structure as the f32
/// reference, i32 accumulate, one requantize per output.
#[allow(clippy::too_many_arguments)]
fn conv3x3_requant_ref(
    xd: &[u8],
    h: usize,
    w: usize,
    cin: usize,
    wts: &[i8],
    bias: &[i32],
    cout: usize,
    m: f64,
    out: &mut [u8],
) {
    debug_assert_eq!(xd.len(), h * w * cin);
    debug_assert_eq!(wts.len(), 9 * cin * cout);
    debug_assert_eq!(out.len(), h * w * cout);
    for y in 0..h {
        for xx in 0..w {
            for oc in 0..cout {
                let mut acc = bias[oc];
                for u in 0..3usize {
                    let yy = y as isize + u as isize - 1;
                    if yy < 0 || yy >= h as isize {
                        continue;
                    }
                    for v in 0..3usize {
                        let xv = xx as isize + v as isize - 1;
                        if xv < 0 || xv >= w as isize {
                            continue;
                        }
                        let base = ((u * 3 + v) * cin) * cout + oc;
                        let px = (yy as usize * w + xv as usize) * cin;
                        for ic in 0..cin {
                            acc += xd[px + ic] as i32 * wts[base + ic * cout] as i32;
                        }
                    }
                }
                out[(y * w + xx) * cout + oc] = requantize(acc, m);
            }
        }
    }
}

/// Optimized int8 conv: tap-major packed weights, contiguous `ic`
/// accumulation (u8×i8 widening products LLVM lowers to 16/32-wide
/// integer dot products), conv rows fanned across the worker pool.
#[allow(clippy::too_many_arguments)]
fn conv3x3_requant_packed(
    xd: &[u8],
    h: usize,
    w: usize,
    cin: usize,
    packed: &[i8],
    bias: &[i32],
    cout: usize,
    m: f64,
    out: &mut [u8],
) {
    debug_assert_eq!(xd.len(), h * w * cin);
    debug_assert_eq!(out.len(), h * w * cout);
    if h == 0 || w == 0 || cout == 0 {
        return;
    }
    let row_len = w * cout;
    let min_rows = (GRAIN_OPS / (w * 9 * cin * cout).max(1)).max(1);
    par::par_row_bands(out, h, row_len, min_rows, |y0, band| {
        for (r, orow) in band.chunks_exact_mut(row_len).enumerate() {
            let y = y0 + r;
            let u_lo = usize::from(y == 0);
            let u_hi = if y + 1 == h { 2 } else { 3 };
            for xx in 0..w {
                let v_lo = usize::from(xx == 0);
                let v_hi = if xx + 1 == w { 2 } else { 3 };
                let opix = &mut orow[xx * cout..(xx + 1) * cout];
                for (oc, o) in opix.iter_mut().enumerate() {
                    let mut acc = bias[oc];
                    for u in u_lo..u_hi {
                        let yy = y + u - 1;
                        for v in v_lo..v_hi {
                            let xv = xx + v - 1;
                            let xrow = &xd[(yy * w + xv) * cin..][..cin];
                            let wrow = &packed[((u * 3 + v) * cout + oc) * cin..][..cin];
                            for ic in 0..cin {
                                acc += xrow[ic] as i32 * wrow[ic] as i32;
                            }
                        }
                    }
                    *o = requantize(acc, m);
                }
            }
        }
    });
}

/// Simd int8 conv: eight output-channel [`I32x8`] lanes over the
/// unpacked HWIO layout (the `oc` axis is innermost and contiguous),
/// widening u8×i8 multiply-accumulate per `(tap, ic)` term, scalar tail
/// for non-lane-multiple widths. Exact-integer arithmetic makes the
/// result bit-identical to the other tiers in any order.
#[allow(clippy::too_many_arguments)]
fn conv3x3_requant_lanes(
    xd: &[u8],
    h: usize,
    w: usize,
    cin: usize,
    wts: &[i8],
    bias: &[i32],
    cout: usize,
    m: f64,
    out: &mut [u8],
) {
    debug_assert_eq!(xd.len(), h * w * cin);
    debug_assert_eq!(wts.len(), 9 * cin * cout);
    debug_assert_eq!(out.len(), h * w * cout);
    if h == 0 || w == 0 || cout == 0 {
        return;
    }
    let row_len = w * cout;
    let min_rows = (GRAIN_OPS / (w * 9 * cin * cout).max(1)).max(1);
    let blocks = cout / LANES;
    par::par_row_bands(out, h, row_len, min_rows, |y0, band| {
        for (r, orow) in band.chunks_exact_mut(row_len).enumerate() {
            let y = y0 + r;
            let u_lo = usize::from(y == 0);
            let u_hi = if y + 1 == h { 2 } else { 3 };
            for xx in 0..w {
                let v_lo = usize::from(xx == 0);
                let v_hi = if xx + 1 == w { 2 } else { 3 };
                let opix = &mut orow[xx * cout..(xx + 1) * cout];
                for blk in 0..blocks {
                    let oc0 = blk * LANES;
                    let mut acc = I32x8::load(&bias[oc0..]);
                    for u in u_lo..u_hi {
                        let yy = y + u - 1;
                        for v in v_lo..v_hi {
                            let xv = xx + v - 1;
                            let px = (yy * w + xv) * cin;
                            let base = ((u * 3 + v) * cin) * cout + oc0;
                            for ic in 0..cin {
                                acc.acc_widening(xd[px + ic], &wts[base + ic * cout..]);
                            }
                        }
                    }
                    for (i, &a) in acc.0.iter().enumerate() {
                        opix[oc0 + i] = requantize(a, m);
                    }
                }
                for oc in blocks * LANES..cout {
                    let mut acc = bias[oc];
                    for u in u_lo..u_hi {
                        let yy = y + u - 1;
                        for v in v_lo..v_hi {
                            let xv = xx + v - 1;
                            let px = (yy * w + xv) * cin;
                            let base = ((u * 3 + v) * cin) * cout + oc;
                            for ic in 0..cin {
                                acc += xd[px + ic] as i32 * wts[base + ic * cout] as i32;
                            }
                        }
                    }
                    opix[oc] = requantize(acc, m);
                }
            }
        }
    });
}

/// Row-pointer 2x2 stride-2 u8 max pool (exact: u8 `max` is a total
/// order, so every tier and reduction order agrees bit-for-bit).
fn maxpool2x2_u8(xd: &[u8], h: usize, w: usize, c: usize, out: &mut [u8]) {
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), oh * ow * c);
    if oh == 0 || ow == 0 || c == 0 {
        return;
    }
    let row_len = w * c;
    for (oy, orow) in out.chunks_exact_mut(ow * c).enumerate() {
        let r0 = &xd[(2 * oy) * row_len..][..row_len];
        let r1 = &xd[(2 * oy + 1) * row_len..][..row_len];
        for ox in 0..ow {
            let base = 2 * ox * c;
            let opix = &mut orow[ox * c..(ox + 1) * c];
            let (a0, a1) = (&r0[base..base + c], &r0[base + c..base + 2 * c]);
            let (b0, b1) = (&r1[base..base + c], &r1[base + c..base + 2 * c]);
            for ch in 0..c {
                opix[ch] = a0[ch].max(a1[ch]).max(b0[ch]).max(b1[ch]);
            }
        }
    }
}

/// [`U8x8`] lane twin of [`maxpool2x2_u8`] (channel lanes of eight,
/// scalar tail) — the Simd tier's pool.
fn maxpool2x2_u8_lanes(xd: &[u8], h: usize, w: usize, c: usize, out: &mut [u8]) {
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), oh * ow * c);
    if oh == 0 || ow == 0 || c == 0 {
        return;
    }
    let row_len = w * c;
    let blocks = c / LANES;
    for (oy, orow) in out.chunks_exact_mut(ow * c).enumerate() {
        let r0 = &xd[(2 * oy) * row_len..][..row_len];
        let r1 = &xd[(2 * oy + 1) * row_len..][..row_len];
        for ox in 0..ow {
            let base = 2 * ox * c;
            let opix = &mut orow[ox * c..(ox + 1) * c];
            let (a0, a1) = (&r0[base..base + c], &r0[base + c..base + 2 * c]);
            let (b0, b1) = (&r1[base..base + c], &r1[base + c..base + 2 * c]);
            for blk in 0..blocks {
                let ch0 = blk * LANES;
                let m = U8x8::load(&a0[ch0..])
                    .max(U8x8::load(&a1[ch0..]))
                    .max(U8x8::load(&b0[ch0..]))
                    .max(U8x8::load(&b1[ch0..]));
                m.store(&mut opix[ch0..]);
            }
            for ch in blocks * LANES..c {
                opix[ch] = a0[ch].max(a1[ch]).max(b0[ch]).max(b1[ch]);
            }
        }
    }
}

/// Backend-dispatched single int8 conv layer (tests and layer-level
/// accuracy pins; the forward pass uses the raw-slice kernels with
/// ping-pong buffers).
pub fn conv3x3_requant(backend: KernelBackend, x: &QuantMap, qc: &QuantConv) -> QuantMap {
    let mut out = QuantMap {
        h: x.h,
        w: x.w,
        c: qc.cout,
        data: vec![0u8; x.h * x.w * qc.cout],
    };
    run_conv(backend, &x.data, x.h, x.w, qc, &mut out.data);
    out
}

/// Backend-dispatched 2x2 u8 max pool.
pub fn maxpool2x2_q(backend: KernelBackend, x: &QuantMap) -> QuantMap {
    let mut out = QuantMap {
        h: x.h / 2,
        w: x.w / 2,
        c: x.c,
        data: vec![0u8; (x.h / 2) * (x.w / 2) * x.c],
    };
    match backend {
        KernelBackend::Simd => maxpool2x2_u8_lanes(&x.data, x.h, x.w, x.c, &mut out.data),
        _ => maxpool2x2_u8(&x.data, x.h, x.w, x.c, &mut out.data),
    }
    out
}

fn run_conv(backend: KernelBackend, xd: &[u8], h: usize, w: usize, qc: &QuantConv, out: &mut [u8]) {
    match backend {
        KernelBackend::Reference => {
            conv3x3_requant_ref(xd, h, w, qc.cin, &qc.w, &qc.bias, qc.cout, qc.m, out)
        }
        KernelBackend::Optimized => {
            conv3x3_requant_packed(xd, h, w, qc.cin, &qc.packed, &qc.bias, qc.cout, qc.m, out)
        }
        KernelBackend::Simd => {
            if qc.cout < LANES {
                // All-tail conv: the packed scalar tier is tuned for it.
                conv3x3_requant_packed(xd, h, w, qc.cin, &qc.packed, &qc.bias, qc.cout, qc.m, out)
            } else {
                conv3x3_requant_lanes(xd, h, w, qc.cin, &qc.w, &qc.bias, qc.cout, qc.m, out)
            }
        }
    }
}

/// Int8 dense with requantized u8 output (fc0): i32 accumulate from the
/// quantized bias, zero-activation skip (post-ReLU u8 maps are sparse).
fn dense_requant(x: &[u8], d: &QuantDense) -> Vec<u8> {
    debug_assert_eq!(x.len(), d.din);
    debug_assert_eq!(d.w.len(), d.din * d.dout);
    let mut acc = d.bias.clone();
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let xi = xv as i32;
        let row = &d.w[i * d.dout..(i + 1) * d.dout];
        for (o, &wv) in row.iter().enumerate() {
            acc[o] += xi * wv as i32;
        }
    }
    acc.iter().map(|&a| requantize(a, d.m)).collect()
}

/// Int8 dense head (fc1): i32 accumulate, dequantized straight to f32
/// logits (no requantize — classification reads the logits directly).
fn dense_logits(x: &[u8], d: &QuantDense) -> Vec<f32> {
    debug_assert_eq!(x.len(), d.din);
    debug_assert_eq!(d.w.len(), d.din * d.dout);
    let mut acc = d.bias.clone();
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let xi = xv as i32;
        let row = &d.w[i * d.dout..(i + 1) * d.dout];
        for (o, &wv) in row.iter().enumerate() {
            acc[o] += xi * wv as i32;
        }
    }
    acc.iter().map(|&a| (a as f64 * d.m) as f32).collect()
}

/// Full int8 forward pass on one 128x128x3 chip → 2 f32 logits.
/// Bit-identical across `ref|opt|simd` and any worker count (pure
/// integer arithmetic between the input quantizer and the final
/// dequantize).
pub fn cnn_forward_q(
    backend: KernelBackend,
    qw: &QuantizedWeights,
    chip: &FeatureMap,
) -> Result<[f32; 2]> {
    if chip.h != 128 || chip.w != 128 || chip.c != 3 {
        return Err(Error::Geometry(format!(
            "ship CNN expects 128x128x3 chips, got {}x{}x{}",
            chip.h, chip.w, chip.c
        )));
    }
    let input = quantize_chip(chip);
    let (mut h, mut w) = (chip.h, chip.w);
    let mut conv_buf: Vec<u8> = Vec::new();
    let mut pool_buf: Vec<u8> = Vec::new();
    for (i, qc) in qw.conv.iter().enumerate() {
        conv_buf.resize(h * w * qc.cout, 0);
        {
            let src: &[u8] = if i == 0 { &input.data } else { &pool_buf };
            run_conv(backend, src, h, w, qc, &mut conv_buf);
        }
        pool_buf.resize((h / 2) * (w / 2) * qc.cout, 0);
        match backend {
            KernelBackend::Simd => maxpool2x2_u8_lanes(&conv_buf, h, w, qc.cout, &mut pool_buf),
            _ => maxpool2x2_u8(&conv_buf, h, w, qc.cout, &mut pool_buf),
        }
        h /= 2;
        w /= 2;
    }
    let hidden = dense_requant(&pool_buf, &qw.fc0);
    let logits = dense_logits(&hidden, &qw.fc1);
    Ok([logits[0], logits[1]])
}

/// Int8 argmax classification — same tie-break rule as the f32 path
/// (`logit[1] > logit[0]`).
pub fn classify_q(
    backend: KernelBackend,
    qw: &QuantizedWeights,
    chip: &FeatureMap,
) -> Result<usize> {
    let l = cnn_forward_q(backend, qw, chip)?;
    Ok(usize::from(l[1] > l[0]))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn requantize_rounds_and_saturates() {
        assert_eq!(requantize(100, 0.5), 50);
        assert_eq!(requantize(5, 0.5), 3); // 2.5 rounds away from zero
        assert_eq!(requantize(-100, 0.5), 0); // folded ReLU
        assert_eq!(requantize(0, 1.0), 0);
        assert_eq!(requantize(255, 1.0), 255);
        assert_eq!(requantize(256, 1.0), 255); // high saturation
        assert_eq!(requantize(i32::MAX, 1.0), 255);
        assert_eq!(requantize(i32::MIN, 1.0), 0);
        assert_eq!(requantize(i32::MAX, 1e-12), 0); // rounds to zero
        assert_eq!(requantize(i32::MIN, -1.0), 255); // sign-flip saturates high
    }

    #[test]
    fn quantize_chip_maps_unit_range_exactly() {
        let chip = FeatureMap::from_data(1, 2, 2, vec![0.0, 1.0, 0.5, -0.25]).unwrap();
        let q = quantize_chip(&chip);
        assert_eq!(q.data, vec![0, 255, 128, 0]); // 127.5 rounds away from zero
        let d = dequantize(&q, 1.0 / 255.0);
        assert!((d.data[1] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn zero_scale_weights_rejected() {
        let mut w = Weights::synthetic_ship(1);
        for v in w.tensors.get_mut("conv2_w").unwrap().data.iter_mut() {
            *v = 0.0;
        }
        let err = QuantizedWeights::from_weights(&w).unwrap_err();
        assert!(err.to_string().contains("zero scale"), "{err}");
    }

    fn random_qconv(rng: &mut Rng, cin: usize, cout: usize) -> QuantConv {
        let w: Vec<i8> = (0..9 * cin * cout)
            .map(|_| ((rng.next_f32() - 0.5) * 254.0) as i8)
            .collect();
        let packed = repack_hwio_i8(&w, cin, cout);
        QuantConv {
            cin,
            cout,
            packed,
            w,
            bias: (0..cout).map(|_| ((rng.next_f32() - 0.5) * 1000.0) as i32).collect(),
            m: 0.003,
            s_w: 1.0,
            s_out: 1.0,
        }
    }

    fn random_qmap(rng: &mut Rng, h: usize, w: usize, c: usize) -> QuantMap {
        QuantMap {
            h,
            w,
            c,
            data: (0..h * w * c).map(|_| (rng.next_f32() * 255.0) as u8).collect(),
        }
    }

    #[test]
    fn conv_tiers_bit_identical() {
        let mut rng = Rng::new(77);
        // Lane-multiple, tail, and sub-lane (Simd falls back) widths.
        for (h, w, cin, cout) in [(6usize, 7usize, 3usize, 8usize), (5, 4, 4, 11), (4, 5, 2, 3)] {
            let qc = random_qconv(&mut rng, cin, cout);
            let x = random_qmap(&mut rng, h, w, cin);
            let r = conv3x3_requant(KernelBackend::Reference, &x, &qc);
            let o = conv3x3_requant(KernelBackend::Optimized, &x, &qc);
            let s = conv3x3_requant(KernelBackend::Simd, &x, &qc);
            assert_eq!(r.data, o.data, "{h}x{w} {cin}->{cout} opt");
            assert_eq!(r.data, s.data, "{h}x{w} {cin}->{cout} simd");
        }
    }

    #[test]
    fn maxpool_tiers_bit_identical() {
        let mut rng = Rng::new(78);
        for (h, w, c) in [(8usize, 8usize, 8usize), (6, 4, 13), (2, 2, 3)] {
            let x = random_qmap(&mut rng, h, w, c);
            let a = maxpool2x2_q(KernelBackend::Reference, &x);
            let b = maxpool2x2_q(KernelBackend::Simd, &x);
            assert_eq!(a.data, b.data, "{h}x{w}x{c}");
        }
    }

    #[test]
    fn forward_rejects_wrong_chip_size() {
        let qw = QuantizedWeights::from_weights(&Weights::synthetic_ship(1)).unwrap();
        let chip = FeatureMap::new(64, 64, 3);
        assert!(cnn_forward_q(KernelBackend::Reference, &qw, &chip).is_err());
    }
}
