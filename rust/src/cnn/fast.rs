//! Optimized (SHAVE-style) CNN inference — the `KernelBackend::Optimized`
//! tier for the ship-detection benchmark.
//!
//! Three restructurings over the scalar [`crate::cnn::layers`] tier:
//!
//! * **weight repacking**: HWIO `(3, 3, Cin, Cout)` weights are repacked
//!   once per layer into tap-major `(tap, Cout, Cin)` so the `ic`
//!   accumulation reads *contiguous* rows of both the feature map and
//!   the weights — the reference's `w[base + ic * cout]` gather strides
//!   by `Cout` and defeats vectorization.
//! * **row-pointer pooling**: `maxpool2x2` walks two row slices instead
//!   of recomputing `(y * w + x) * c + ch` per element.
//! * **ping-pong buffers**: `cnn_forward_opt` reuses two scratch
//!   feature-map buffers across all four conv/pool stages instead of
//!   cloning the input chip and allocating per layer.
//!
//! Conv rows fan out across cores via [`crate::util::par`]. The scalar
//! tier stays the groundtruth; `tests/kernel_equivalence.rs` pins the
//! two to each other (≤1e-5 relative).

use crate::cnn::layers::{dense, FeatureMap};
use crate::cnn::weights::Weights;
use crate::error::{Error, Result};
use crate::util::par;
use crate::util::par::GRAIN_OPS;

/// Repack HWIO `(3, 3, Cin, Cout)` into tap-major `(tap, Cout, Cin)`:
/// `packed[(tap * cout + oc) * cin + ic] = w[(tap * cin + ic) * cout + oc]`.
fn repack_hwio(w: &[f32], cin: usize, cout: usize) -> Vec<f32> {
    debug_assert_eq!(w.len(), 9 * cin * cout);
    let mut packed = vec![0f32; 9 * cout * cin];
    for tap in 0..9 {
        for ic in 0..cin {
            for oc in 0..cout {
                packed[(tap * cout + oc) * cin + ic] = w[(tap * cin + ic) * cout + oc];
            }
        }
    }
    packed
}

/// Core conv kernel on raw NHWC data with pre-packed weights, writing
/// into a caller-owned buffer (ping-pong reuse across layers).
#[allow(clippy::too_many_arguments)]
fn conv3x3_relu_packed(
    xd: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    packed: &[f32],
    b: &[f32],
    cout: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), h * w * cin);
    debug_assert_eq!(out.len(), h * w * cout);
    if h == 0 || w == 0 || cout == 0 {
        return;
    }
    let row_len = w * cout;
    let min_rows = (GRAIN_OPS / (w * 9 * cin * cout).max(1)).max(1);
    par::par_row_bands(out, h, row_len, min_rows, |y0, band| {
        for (r, orow) in band.chunks_exact_mut(row_len).enumerate() {
            let y = y0 + r;
            // Clamped tap windows (same term order as the reference:
            // u-major, v, then ic).
            let u_lo = usize::from(y == 0);
            let u_hi = if y + 1 == h { 2 } else { 3 };
            for xx in 0..w {
                let v_lo = usize::from(xx == 0);
                let v_hi = if xx + 1 == w { 2 } else { 3 };
                let opix = &mut orow[xx * cout..(xx + 1) * cout];
                for (oc, o) in opix.iter_mut().enumerate() {
                    let mut acc = b[oc];
                    for u in u_lo..u_hi {
                        let yy = y + u - 1;
                        for v in v_lo..v_hi {
                            let xv = xx + v - 1;
                            let xrow = &xd[(yy * w + xv) * cin..][..cin];
                            let wrow = &packed[((u * 3 + v) * cout + oc) * cin..][..cin];
                            for ic in 0..cin {
                                acc += xrow[ic] * wrow[ic];
                            }
                        }
                    }
                    *o = acc.max(0.0);
                }
            }
        }
    });
}

/// Row-pointer 2x2 stride-2 max pool into a caller-owned buffer.
fn maxpool2x2_packed(xd: &[f32], h: usize, w: usize, c: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), oh * ow * c);
    if oh == 0 || ow == 0 || c == 0 {
        return;
    }
    let row_len = w * c;
    for (oy, orow) in out.chunks_exact_mut(ow * c).enumerate() {
        let r0 = &xd[(2 * oy) * row_len..][..row_len];
        let r1 = &xd[(2 * oy + 1) * row_len..][..row_len];
        for ox in 0..ow {
            let base = 2 * ox * c;
            let opix = &mut orow[ox * c..(ox + 1) * c];
            let (a0, a1) = (&r0[base..base + c], &r0[base + c..base + 2 * c]);
            let (b0, b1) = (&r1[base..base + c], &r1[base + c..base + 2 * c]);
            for ch in 0..c {
                opix[ch] = a0[ch].max(a1[ch]).max(b0[ch]).max(b1[ch]);
            }
        }
    }
}

/// Optimized twin of [`crate::cnn::layers::conv3x3_relu`].
pub fn conv3x3_relu_opt(x: &FeatureMap, w: &[f32], b: &[f32], cout: usize) -> FeatureMap {
    let packed = repack_hwio(w, x.c, cout);
    let mut out = FeatureMap::new(x.h, x.w, cout);
    conv3x3_relu_packed(&x.data, x.h, x.w, x.c, &packed, b, cout, &mut out.data);
    out
}

/// Optimized twin of [`crate::cnn::layers::maxpool2x2`]. Bit-exact.
pub fn maxpool2x2_opt(x: &FeatureMap) -> FeatureMap {
    let mut out = FeatureMap::new(x.h / 2, x.w / 2, x.c);
    maxpool2x2_packed(&x.data, x.h, x.w, x.c, &mut out.data);
    out
}

/// Optimized twin of [`crate::cnn::layers::cnn_forward`]: same 6-layer
/// network, ping-pong scratch buffers, no input clone.
pub fn cnn_forward_opt(weights: &Weights, chip: &FeatureMap) -> Result<[f32; 2]> {
    if chip.h != 128 || chip.w != 128 || chip.c != 3 {
        return Err(Error::Geometry(format!(
            "ship CNN expects 128x128x3 chips, got {}x{}x{}",
            chip.h, chip.w, chip.c
        )));
    }
    let (mut h, mut w, mut cin) = (chip.h, chip.w, chip.c);
    let mut conv_buf: Vec<f32> = Vec::new();
    let mut pool_buf: Vec<f32> = Vec::new();
    for i in 0..4 {
        let wt = weights.get(&format!("conv{i}_w"))?;
        let bt = weights.get(&format!("conv{i}_b"))?;
        let cout = *wt.dims.last().unwrap();
        let packed = repack_hwio(&wt.data, cin, cout);
        conv_buf.resize(h * w * cout, 0.0);
        {
            let src: &[f32] = if i == 0 { &chip.data } else { &pool_buf };
            conv3x3_relu_packed(src, h, w, cin, &packed, &bt.data, cout, &mut conv_buf);
        }
        pool_buf.resize((h / 2) * (w / 2) * cout, 0.0);
        maxpool2x2_packed(&conv_buf, h, w, cout, &mut pool_buf);
        h /= 2;
        w /= 2;
        cin = cout;
    }
    let fc0w = weights.get("fc0_w")?;
    let fc0b = weights.get("fc0_b")?;
    let hidden = dense(&pool_buf, &fc0w.data, &fc0b.data, 57, true);
    let fc1w = weights.get("fc1_w")?;
    let fc1b = weights.get("fc1_b")?;
    let logits = dense(&hidden, &fc1w.data, &fc1b.data, 2, false);
    Ok([logits[0], logits[1]])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layers;
    use crate::util::rng::Rng;

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-5 * (1.0 + a.abs().max(b.abs()))
    }

    fn random_fm(rng: &mut Rng, h: usize, w: usize, c: usize) -> FeatureMap {
        FeatureMap::from_data(h, w, c, (0..h * w * c).map(|_| rng.next_f32() - 0.5).collect())
            .unwrap()
    }

    #[test]
    fn conv_matches_reference_including_borders() {
        let mut rng = Rng::new(11);
        let shapes = [(6usize, 7usize, 3usize, 4usize), (1, 9, 2, 3), (5, 1, 4, 2), (1, 1, 1, 1)];
        for (h, w, cin, cout) in shapes {
            let x = random_fm(&mut rng, h, w, cin);
            let wts: Vec<f32> = (0..9 * cin * cout).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..cout).map(|_| rng.next_f32() - 0.5).collect();
            let r = layers::conv3x3_relu(&x, &wts, &b, cout);
            let o = conv3x3_relu_opt(&x, &wts, &b, cout);
            assert!(
                r.data.iter().zip(&o.data).all(|(&a, &bb)| close(a, bb)),
                "{h}x{w} {cin}->{cout}"
            );
        }
    }

    #[test]
    fn maxpool_bit_exact() {
        let mut rng = Rng::new(12);
        for (h, w, c) in [(8usize, 8usize, 3usize), (9, 7, 2), (2, 2, 5), (1, 4, 2)] {
            let x = random_fm(&mut rng, h, w, c);
            assert_eq!(layers::maxpool2x2(&x).data, maxpool2x2_opt(&x).data, "{h}x{w}x{c}");
        }
    }

    #[test]
    fn forward_rejects_wrong_chip_size() {
        let w = Weights::default();
        let chip = FeatureMap::new(64, 64, 3);
        assert!(cnn_forward_opt(&w, &chip).is_err());
    }
}
