//! Explicit-SIMD CNN inference — the `KernelBackend::Simd` tier for the
//! ship-detection benchmark.
//!
//! Vectorizes **across output channels**: the HWIO weight layout keeps
//! the `Cout` axis innermost (`w[((u*3 + v)*cin + ic)*cout + oc]`), so
//! eight consecutive `oc` lanes load one contiguous weight block per
//! `(tap, ic)` term and broadcast the single activation across the
//! lanes — no repacking pass at all (the Optimized tier's repack exists
//! to serve its `ic`-contiguous scalar loop; lanes over `oc` make the
//! original layout the fast one). Every lane replays the scalar
//! reference's exact accumulation order (`u`, `v`, `ic`;
//! bias-initialized; multiply-then-add; final `max(0.0)`), so the conv
//! is **bit-identical to the Reference tier**, not merely ε-close. The
//! ship net's conv widths (8/16/32/32) are all lane multiples; a
//! non-multiple `cout` runs its remainder through an identical scalar
//! tail. Maxpool lanes over the (innermost, contiguous) channel axis
//! with the reference's `max` order — exact by construction.
//!
//! Fallback rule: a conv narrower than one lane block (`cout < 8`) is
//! all tail — route it to the Optimized tier, which is tuned for
//! exactly that scalar shape.

use crate::cnn::fast;
use crate::cnn::layers::{dense, FeatureMap};
use crate::cnn::weights::Weights;
use crate::error::{Error, Result};
use crate::util::lanes::{F32x8, LANES};
use crate::util::par;
use crate::util::par::GRAIN_OPS;

/// Core conv kernel on raw NHWC data with **unpacked** HWIO weights,
/// eight `oc` lanes per step, into a caller-owned buffer.
#[allow(clippy::too_many_arguments)]
fn conv3x3_relu_lanes(
    xd: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    wts: &[f32],
    b: &[f32],
    cout: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(xd.len(), h * w * cin);
    debug_assert_eq!(wts.len(), 9 * cin * cout);
    debug_assert_eq!(out.len(), h * w * cout);
    if h == 0 || w == 0 || cout == 0 {
        return;
    }
    let row_len = w * cout;
    let min_rows = (GRAIN_OPS / (w * 9 * cin * cout).max(1)).max(1);
    let blocks = cout / LANES;
    par::par_row_bands(out, h, row_len, min_rows, |y0, band| {
        for (r, orow) in band.chunks_exact_mut(row_len).enumerate() {
            let y = y0 + r;
            let u_lo = usize::from(y == 0);
            let u_hi = if y + 1 == h { 2 } else { 3 };
            for xx in 0..w {
                let v_lo = usize::from(xx == 0);
                let v_hi = if xx + 1 == w { 2 } else { 3 };
                let opix = &mut orow[xx * cout..(xx + 1) * cout];
                for blk in 0..blocks {
                    let oc0 = blk * LANES;
                    let mut acc = F32x8::load(&b[oc0..]);
                    for u in u_lo..u_hi {
                        let yy = y + u - 1;
                        for v in v_lo..v_hi {
                            let xv = xx + v - 1;
                            let px = (yy * w + xv) * cin;
                            let base = ((u * 3 + v) * cin) * cout + oc0;
                            for ic in 0..cin {
                                acc.acc_scaled(
                                    xd[px + ic],
                                    F32x8::load(&wts[base + ic * cout..]),
                                );
                            }
                        }
                    }
                    acc.relu().store(&mut opix[oc0..]);
                }
                // Scalar oc tail: the reference loop verbatim.
                for oc in blocks * LANES..cout {
                    let mut acc = b[oc];
                    for u in u_lo..u_hi {
                        let yy = y + u - 1;
                        for v in v_lo..v_hi {
                            let xv = xx + v - 1;
                            let px = (yy * w + xv) * cin;
                            let base = ((u * 3 + v) * cin) * cout + oc;
                            for ic in 0..cin {
                                acc += xd[px + ic] * wts[base + ic * cout];
                            }
                        }
                    }
                    opix[oc] = acc.max(0.0);
                }
            }
        }
    });
}

/// Row-pointer 2x2 stride-2 max pool, channel lanes of eight.
fn maxpool2x2_lanes(xd: &[f32], h: usize, w: usize, c: usize, out: &mut [f32]) {
    let (oh, ow) = (h / 2, w / 2);
    debug_assert_eq!(out.len(), oh * ow * c);
    if oh == 0 || ow == 0 || c == 0 {
        return;
    }
    let row_len = w * c;
    let blocks = c / LANES;
    for (oy, orow) in out.chunks_exact_mut(ow * c).enumerate() {
        let r0 = &xd[(2 * oy) * row_len..][..row_len];
        let r1 = &xd[(2 * oy + 1) * row_len..][..row_len];
        for ox in 0..ow {
            let base = 2 * ox * c;
            let opix = &mut orow[ox * c..(ox + 1) * c];
            let (a0, a1) = (&r0[base..base + c], &r0[base + c..base + 2 * c]);
            let (b0, b1) = (&r1[base..base + c], &r1[base + c..base + 2 * c]);
            for blk in 0..blocks {
                let ch0 = blk * LANES;
                let m = F32x8::load(&a0[ch0..])
                    .max(F32x8::load(&a1[ch0..]))
                    .max(F32x8::load(&b0[ch0..]))
                    .max(F32x8::load(&b1[ch0..]));
                m.store(&mut opix[ch0..]);
            }
            for ch in blocks * LANES..c {
                opix[ch] = a0[ch].max(a1[ch]).max(b0[ch]).max(b1[ch]);
            }
        }
    }
}

/// Simd twin of [`crate::cnn::layers::conv3x3_relu`]. Bit-identical to
/// the reference; `cout < 8` falls back to the Optimized tier.
pub fn conv3x3_relu_simd(x: &FeatureMap, w: &[f32], b: &[f32], cout: usize) -> FeatureMap {
    if cout < LANES {
        return fast::conv3x3_relu_opt(x, w, b, cout);
    }
    let mut out = FeatureMap::new(x.h, x.w, cout);
    conv3x3_relu_lanes(&x.data, x.h, x.w, x.c, w, b, cout, &mut out.data);
    out
}

/// Simd twin of [`crate::cnn::layers::maxpool2x2`]. Bit-exact.
pub fn maxpool2x2_simd(x: &FeatureMap) -> FeatureMap {
    let mut out = FeatureMap::new(x.h / 2, x.w / 2, x.c);
    maxpool2x2_lanes(&x.data, x.h, x.w, x.c, &mut out.data);
    out
}

/// Simd twin of [`crate::cnn::layers::cnn_forward`]: same 6-layer
/// network, ping-pong scratch buffers, lane kernels, no weight repack.
pub fn cnn_forward_simd(weights: &Weights, chip: &FeatureMap) -> Result<[f32; 2]> {
    if chip.h != 128 || chip.w != 128 || chip.c != 3 {
        return Err(Error::Geometry(format!(
            "ship CNN expects 128x128x3 chips, got {}x{}x{}",
            chip.h, chip.w, chip.c
        )));
    }
    let (mut h, mut w, mut cin) = (chip.h, chip.w, chip.c);
    let mut conv_buf: Vec<f32> = Vec::new();
    let mut pool_buf: Vec<f32> = Vec::new();
    for i in 0..4 {
        let wt = weights.get(&format!("conv{i}_w"))?;
        let bt = weights.get(&format!("conv{i}_b"))?;
        let cout = *wt.dims.last().unwrap();
        conv_buf.resize(h * w * cout, 0.0);
        {
            let src: &[f32] = if i == 0 { &chip.data } else { &pool_buf };
            conv3x3_relu_lanes(src, h, w, cin, &wt.data, &bt.data, cout, &mut conv_buf);
        }
        pool_buf.resize((h / 2) * (w / 2) * cout, 0.0);
        maxpool2x2_lanes(&conv_buf, h, w, cout, &mut pool_buf);
        h /= 2;
        w /= 2;
        cin = cout;
    }
    let fc0w = weights.get("fc0_w")?;
    let fc0b = weights.get("fc0_b")?;
    let hidden = dense(&pool_buf, &fc0w.data, &fc0b.data, 57, true);
    let fc1w = weights.get("fc1_w")?;
    let fc1b = weights.get("fc1_b")?;
    let logits = dense(&hidden, &fc1w.data, &fc1b.data, 2, false);
    Ok([logits[0], logits[1]])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cnn::layers;
    use crate::util::rng::Rng;

    fn random_fm(rng: &mut Rng, h: usize, w: usize, c: usize) -> FeatureMap {
        FeatureMap::from_data(h, w, c, (0..h * w * c).map(|_| rng.next_f32() - 0.5).collect())
            .unwrap()
    }

    #[test]
    fn conv_bit_identical_to_reference_lane_multiple_and_tail() {
        let mut rng = Rng::new(31);
        // cout 8 (one block), 16 (two), 11 (block + 3-wide tail).
        for (h, w, cin, cout) in [(6usize, 7usize, 3usize, 8usize), (5, 4, 2, 16), (4, 5, 3, 11)] {
            let x = random_fm(&mut rng, h, w, cin);
            let wts: Vec<f32> = (0..9 * cin * cout).map(|_| rng.next_f32() - 0.5).collect();
            let b: Vec<f32> = (0..cout).map(|_| rng.next_f32() - 0.5).collect();
            let r = layers::conv3x3_relu(&x, &wts, &b, cout);
            let s = conv3x3_relu_simd(&x, &wts, &b, cout);
            for (i, (a, bb)) in r.data.iter().zip(&s.data).enumerate() {
                assert_eq!(a.to_bits(), bb.to_bits(), "{h}x{w} {cin}->{cout} idx {i}");
            }
        }
    }

    #[test]
    fn conv_narrow_cout_falls_back() {
        let mut rng = Rng::new(32);
        let x = random_fm(&mut rng, 5, 5, 2);
        let wts: Vec<f32> = (0..9 * 2 * 3).map(|_| rng.next_f32() - 0.5).collect();
        let b = vec![0.1f32, -0.2, 0.3];
        let r = layers::conv3x3_relu(&x, &wts, &b, 3);
        let s = conv3x3_relu_simd(&x, &wts, &b, 3);
        for (a, bb) in r.data.iter().zip(&s.data) {
            let tol = 1e-5 * (1.0 + a.abs().max(bb.abs()));
            assert!((a - bb).abs() <= tol);
        }
    }

    #[test]
    fn maxpool_bit_exact_including_tail_channels() {
        let mut rng = Rng::new(33);
        for (h, w, c) in [(8usize, 8usize, 8usize), (6, 4, 16), (4, 6, 13), (2, 2, 3)] {
            let x = random_fm(&mut rng, h, w, c);
            assert_eq!(layers::maxpool2x2(&x).data, maxpool2x2_simd(&x).data, "{h}x{w}x{c}");
        }
    }

    #[test]
    fn forward_rejects_wrong_chip_size() {
        let w = Weights::default();
        let chip = FeatureMap::new(64, 64, 3);
        assert!(cnn_forward_simd(&w, &chip).is_err());
    }
}
