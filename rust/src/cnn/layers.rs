//! Scalar fp32 CNN inference (NHWC) — the LEON-baseline engine and host
//! groundtruth for the ship-detection benchmark. Layer semantics match
//! `python/compile/kernels/ref.py` exactly ('same' padding conv + bias +
//! ReLU, 2x2 max pool, dense).

use crate::cnn::weights::Weights;
use crate::error::{Error, Result};

/// NHWC feature map (single image: N=1 implied).
#[derive(Clone, Debug)]
pub struct FeatureMap {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl FeatureMap {
    pub fn new(h: usize, w: usize, c: usize) -> FeatureMap {
        FeatureMap {
            h,
            w,
            c,
            data: vec![0.0; h * w * c],
        }
    }

    pub fn from_data(h: usize, w: usize, c: usize, data: Vec<f32>) -> Result<FeatureMap> {
        if data.len() != h * w * c {
            return Err(Error::Geometry(format!(
                "feature map {h}x{w}x{c} needs {} values, got {}",
                h * w * c,
                data.len()
            )));
        }
        Ok(FeatureMap { h, w, c, data })
    }

    #[inline]
    pub fn at(&self, y: usize, x: usize, ch: usize) -> f32 {
        self.data[(y * self.w + x) * self.c + ch]
    }
}

/// 'Same' 3x3 conv + bias + ReLU. w dims (3, 3, Cin, Cout) HWIO.
pub fn conv3x3_relu(x: &FeatureMap, w: &[f32], b: &[f32], cout: usize) -> FeatureMap {
    let cin = x.c;
    debug_assert_eq!(w.len(), 9 * cin * cout);
    let mut out = FeatureMap::new(x.h, x.w, cout);
    for y in 0..x.h {
        for xx in 0..x.w {
            for oc in 0..cout {
                let mut acc = b[oc];
                for u in 0..3usize {
                    let yy = y as isize + u as isize - 1;
                    if yy < 0 || yy >= x.h as isize {
                        continue;
                    }
                    for v in 0..3usize {
                        let xv = xx as isize + v as isize - 1;
                        if xv < 0 || xv >= x.w as isize {
                            continue;
                        }
                        let base = ((u * 3 + v) * cin) * cout + oc;
                        let px = (yy as usize * x.w + xv as usize) * cin;
                        for ic in 0..cin {
                            acc += x.data[px + ic] * w[base + ic * cout];
                        }
                    }
                }
                out.data[(y * x.w + xx) * cout + oc] = acc.max(0.0);
            }
        }
    }
    out
}

/// 2x2 stride-2 max pool.
pub fn maxpool2x2(x: &FeatureMap) -> FeatureMap {
    let mut out = FeatureMap::new(x.h / 2, x.w / 2, x.c);
    for y in 0..out.h {
        for xx in 0..out.w {
            for ch in 0..x.c {
                let m = x
                    .at(2 * y, 2 * xx, ch)
                    .max(x.at(2 * y, 2 * xx + 1, ch))
                    .max(x.at(2 * y + 1, 2 * xx, ch))
                    .max(x.at(2 * y + 1, 2 * xx + 1, ch));
                out.data[(y * out.w + xx) * x.c + ch] = m;
            }
        }
    }
    out
}

/// Dense layer: y = x @ w + b, optional ReLU. w dims (Din, Dout).
pub fn dense(x: &[f32], w: &[f32], b: &[f32], dout: usize, relu: bool) -> Vec<f32> {
    let din = x.len();
    debug_assert_eq!(w.len(), din * dout);
    let mut out = b.to_vec();
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue; // post-ReLU activations are sparse
        }
        let row = &w[i * dout..(i + 1) * dout];
        for (o, &wv) in row.iter().enumerate() {
            out[o] += xv * wv;
        }
    }
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
    out
}

/// Full 6-layer forward pass on one 128x128x3 chip -> 2 logits.
pub fn cnn_forward(weights: &Weights, chip: &FeatureMap) -> Result<[f32; 2]> {
    if chip.h != 128 || chip.w != 128 || chip.c != 3 {
        return Err(Error::Geometry(format!(
            "ship CNN expects 128x128x3 chips, got {}x{}x{}",
            chip.h, chip.w, chip.c
        )));
    }
    let mut fm = chip.clone();
    for i in 0..4 {
        let w = weights.get(&format!("conv{i}_w"))?;
        let b = weights.get(&format!("conv{i}_b"))?;
        let cout = *w.dims.last().unwrap();
        fm = conv3x3_relu(&fm, &w.data, &b.data, cout);
        fm = maxpool2x2(&fm);
    }
    let fc0w = weights.get("fc0_w")?;
    let fc0b = weights.get("fc0_b")?;
    let hidden = dense(&fm.data, &fc0w.data, &fc0b.data, 57, true);
    let fc1w = weights.get("fc1_w")?;
    let fc1b = weights.get("fc1_b")?;
    let logits = dense(&hidden, &fc1w.data, &fc1b.data, 2, false);
    Ok([logits[0], logits[1]])
}

/// Argmax classification on the scalar tier (delegates to the
/// backend-dispatched [`crate::cnn::classify`] so the argmax rule
/// lives in one place).
pub fn classify(weights: &Weights, chip: &FeatureMap) -> Result<usize> {
    crate::cnn::classify(crate::KernelBackend::Reference, weights, chip)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn conv_identity_filter_passes_through() {
        // Single channel, center tap 1.0 -> identity (+ReLU).
        let mut x = FeatureMap::new(4, 4, 1);
        for (i, v) in x.data.iter_mut().enumerate() {
            *v = i as f32 / 10.0;
        }
        let mut w = vec![0f32; 9];
        w[4] = 1.0; // center tap (u=1,v=1)
        let out = conv3x3_relu(&x, &w, &[0.0], 1);
        assert_eq!(out.data, x.data);
    }

    #[test]
    fn conv_relu_clamps_negative() {
        let x = FeatureMap::from_data(2, 2, 1, vec![1.0; 4]).unwrap();
        let w = vec![0f32; 9];
        let out = conv3x3_relu(&x, &w, &[-5.0], 1);
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn conv_channel_mixing() {
        // 1x1 image, 2 channels in, 1 out: out = x0*w0 + x1*w1 + b.
        let x = FeatureMap::from_data(1, 1, 2, vec![2.0, 3.0]).unwrap();
        let mut w = vec![0f32; 9 * 2];
        // center tap (u=1,v=1): base index ((1*3+1)*2)*1 = 8.
        w[8] = 10.0; // ic=0
        w[9] = 100.0; // ic=1
        let out = conv3x3_relu(&x, &w, &[1.0], 1);
        assert_eq!(out.data, vec![2.0 * 10.0 + 3.0 * 100.0 + 1.0]);
    }

    #[test]
    fn maxpool_explicit() {
        let x = FeatureMap::from_data(
            4,
            4,
            1,
            (0..16).map(|v| v as f32).collect(),
        )
        .unwrap();
        let out = maxpool2x2(&x);
        assert_eq!(out.data, vec![5.0, 7.0, 13.0, 15.0]);
    }

    #[test]
    fn dense_explicit() {
        let x = vec![1.0, 2.0];
        let w = vec![1.0, 10.0, 100.0, 1000.0]; // (2, 2) row-major
        let b = vec![0.5, -0.5];
        let out = dense(&x, &w, &b, 2, false);
        assert_eq!(out, vec![1.0 + 200.0 + 0.5, 10.0 + 2000.0 - 0.5]);
    }

    #[test]
    fn dense_skips_zeros_correctly() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..64)
            .map(|_| {
                let v = rng.next_f32() - 0.5;
                if v < 0.0 {
                    0.0
                } else {
                    v
                }
            })
            .collect();
        let w: Vec<f32> = (0..64 * 8).map(|_| rng.next_f32() - 0.5).collect();
        let b = vec![0.1f32; 8];
        let fast = dense(&x, &w, &b, 8, false);
        // Naive reference.
        let mut slow = b.clone();
        for i in 0..64 {
            for o in 0..8 {
                slow[o] += x[i] * w[i * 8 + o];
            }
        }
        for (a, bb) in fast.iter().zip(&slow) {
            assert!((a - bb).abs() < 1e-5);
        }
    }

    #[test]
    fn forward_rejects_wrong_chip_size() {
        let w = Weights::default();
        let chip = FeatureMap::new(64, 64, 3);
        assert!(cnn_forward(&w, &chip).is_err());
    }

    #[test]
    fn forward_with_trained_weights_if_built() {
        let dir = crate::config::default_artifacts_dir();
        let path = format!("{dir}/cnn_weights.bin");
        if !std::path::Path::new(&path).exists() {
            return;
        }
        let weights = Weights::load(&path).unwrap();
        let mut rng = Rng::new(9);
        let chip = FeatureMap::from_data(
            128,
            128,
            3,
            (0..128 * 128 * 3).map(|_| rng.next_f32()).collect(),
        )
        .unwrap();
        let logits = cnn_forward(&weights, &chip).unwrap();
        assert!(logits.iter().all(|v| v.is_finite()));
    }
}
