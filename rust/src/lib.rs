//! # spacecodesign — FPGA & VPU co-processing for space applications
//!
//! A full-system reproduction of V. Leon et al., *"FPGA & VPU Co-Processing
//! in Space Applications: Development and Testing with DSP/AI Benchmarks"*
//! (ICECS 2021), on a simulated testbed (see DESIGN.md for the hardware
//! substitution map).
//!
//! The crate is the L3 layer of a three-layer stack:
//!
//! * **L1/L2 (build time)**: the DSP/AI benchmarks are Pallas kernels
//!   composed into JAX graphs, AOT-lowered to HLO text under `artifacts/`.
//! * **L3 (this crate)**: a cycle-accounted simulation of the FPGA framing
//!   processor (CIF/LCD interface HDL, FIFOs, CRC), a timing/power model of
//!   the Myriad2 VPU (2×LEON, 12×SHAVE, DMA, CMX/DRAM), and the system
//!   coordinator implementing the paper's Unmasked/Masked I/O modes.
//!   Benchmark *numerics* are real: the coordinator executes the AOT
//!   artifacts through the PJRT CPU client (`runtime`).
//!
//! Layout follows DESIGN.md §8; every paper table/figure has a bench
//! target under `rust/benches/`.

pub mod config;
pub mod error;
pub mod recovery;
pub mod util;

/// Which kernel tier executes the DSP/CNN hot paths.
///
/// The crate keeps **three implementations of every hot kernel**,
/// mirroring the paper's LEON-vs-SHAVE split (and the SHAVEs' explicit
/// 128-bit vector ISA on top of plain loop code):
///
/// * [`KernelBackend::Reference`] — the scalar LEON-baseline code
///   (`dsp::conv`, `dsp::binning`, `cnn::layers`). Simple, obviously
///   correct, and the pinned groundtruth.
/// * [`KernelBackend::Optimized`] — the SHAVE-style tier (`dsp::fast`,
///   `cnn::fast`): interior/border split to remove per-tap bounds
///   checks, contiguous inner loops that LLVM auto-vectorizes, and
///   multi-core row fan-out via [`util::par`] (the software analogue of
///   the 12-SHAVE band split).
/// * [`KernelBackend::Simd`] — the explicit-vector tier (`dsp::simd`,
///   `cnn::simd`, the widened CRC slicing kernel): fixed
///   8-lane `[f32; 8]` structs with unrolled arithmetic
///   ([`util::lanes`]), stable-toolchain only. Per-kernel fallback to
///   the Optimized tier on shapes the lane kernels do not cover
///   (degenerate interiors); lane arithmetic keeps the scalar tiers'
///   per-element operation order, so the f32 kernels are bit-identical
///   to Optimized and the integer kernels bit-identical to Reference.
///
/// `tests/kernel_equivalence.rs` pins `Optimized == Reference` and
/// `Simd == Reference` on randomized inputs (exact for
/// integer/CRC/width kernels, ≤1e-5 relative for f32 conv/CNN).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum KernelBackend {
    /// Scalar LEON-baseline kernels — the pinned groundtruth.
    Reference,
    /// Interior/border-split, auto-vectorized, multi-core fan-out tier.
    #[default]
    Optimized,
    /// Explicit fixed-lane vector tier; falls back to `Optimized`
    /// per-kernel where lanes do not apply.
    Simd,
}

impl KernelBackend {
    /// Select from `SPACECODESIGN_BACKEND` (case-insensitive
    /// `reference`/`ref` forces the scalar tier, `optimized`/`opt` the
    /// fast tier, `simd` the explicit-lane tier), defaulting to
    /// [`KernelBackend::Optimized`]. An unrecognized value warns on
    /// stderr rather than silently running the wrong tier in a
    /// strict-pinning run.
    pub fn from_env() -> KernelBackend {
        match std::env::var("SPACECODESIGN_BACKEND") {
            Ok(v) => KernelBackend::parse(&v).unwrap_or_else(|| {
                eprintln!(
                    "warning: unrecognized SPACECODESIGN_BACKEND='{v}', \
                     using the default (optimized)"
                );
                KernelBackend::Optimized
            }),
            Err(_) => KernelBackend::Optimized,
        }
    }

    /// Parse a tier name (case-insensitive; `reference`/`ref`,
    /// `optimized`/`opt`, `simd`) — the one spelling table shared by
    /// the env var, the CLI flag, and `config::ResolvedConfig`.
    pub fn parse(s: &str) -> Option<KernelBackend> {
        match s.to_ascii_lowercase().as_str() {
            "reference" | "ref" => Some(KernelBackend::Reference),
            "optimized" | "opt" => Some(KernelBackend::Optimized),
            "simd" => Some(KernelBackend::Simd),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelBackend::Reference => "reference",
            KernelBackend::Optimized => "optimized",
            KernelBackend::Simd => "simd",
        }
    }
}

/// Arithmetic precision of the CNN inference path.
///
/// Orthogonal to [`KernelBackend`]: every backend tier has both an f32
/// and an int8 implementation of the ship-CNN forward pass, so the two
/// knobs compose freely (`ref|opt|simd` × `f32|int8`).
///
/// * [`Precision::F32`] — the default single-precision path, bit-exact
///   with every prior PR under all existing CI legs.
/// * [`Precision::Int8`] — per-layer symmetric quantization
///   (`cnn::quant`): u8 activations, i8 weights, i32 accumulators with
///   a single rounding/saturating requantize per layer. Pure integer
///   arithmetic, so results are bit-reproducible across worker counts
///   and backend tiers by construction.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Single-precision float inference — the pinned default.
    #[default]
    F32,
    /// Per-layer symmetric int8 quantized inference (`cnn::quant`).
    Int8,
}

impl Precision {
    /// Select from `SPACECODESIGN_PRECISION` (case-insensitive `f32` /
    /// `fp32` / `float` or `int8` / `i8`), defaulting to
    /// [`Precision::F32`]. An unrecognized value warns on stderr rather
    /// than silently running the wrong precision.
    pub fn from_env() -> Precision {
        match std::env::var("SPACECODESIGN_PRECISION") {
            Ok(v) => Precision::parse(&v).unwrap_or_else(|| {
                eprintln!(
                    "warning: unrecognized SPACECODESIGN_PRECISION='{v}', \
                     using the default (f32)"
                );
                Precision::F32
            }),
            Err(_) => Precision::F32,
        }
    }

    /// Parse a precision name (case-insensitive; `f32`/`fp32`/`float`,
    /// `int8`/`i8`) — the one spelling table shared by the env var, the
    /// CLI flag, and `config::ResolvedConfig`.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float" => Some(Precision::F32),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

pub mod fabric;
pub mod iface;
pub mod vpu;

pub mod compress;
pub mod dsp;
pub mod render;
pub mod cnn;

pub mod fpga;
pub mod runtime;
pub mod coordinator;
pub mod bench_model;

pub use error::{Error, Result};
