//! # spacecodesign — FPGA & VPU co-processing for space applications
//!
//! A full-system reproduction of V. Leon et al., *"FPGA & VPU Co-Processing
//! in Space Applications: Development and Testing with DSP/AI Benchmarks"*
//! (ICECS 2021), on a simulated testbed (see DESIGN.md for the hardware
//! substitution map).
//!
//! The crate is the L3 layer of a three-layer stack:
//!
//! * **L1/L2 (build time)**: the DSP/AI benchmarks are Pallas kernels
//!   composed into JAX graphs, AOT-lowered to HLO text under `artifacts/`.
//! * **L3 (this crate)**: a cycle-accounted simulation of the FPGA framing
//!   processor (CIF/LCD interface HDL, FIFOs, CRC), a timing/power model of
//!   the Myriad2 VPU (2×LEON, 12×SHAVE, DMA, CMX/DRAM), and the system
//!   coordinator implementing the paper's Unmasked/Masked I/O modes.
//!   Benchmark *numerics* are real: the coordinator executes the AOT
//!   artifacts through the PJRT CPU client (`runtime`).
//!
//! Layout follows DESIGN.md §8; every paper table/figure has a bench
//! target under `rust/benches/`.

pub mod config;
pub mod error;
pub mod util;

pub mod fabric;
pub mod iface;
pub mod vpu;

pub mod compress;
pub mod dsp;
pub mod render;
pub mod cnn;

pub mod fpga;
pub mod runtime;
pub mod coordinator;
pub mod bench_model;

pub use error::{Error, Result};
