//! FPGA internal 32-bit bus model (paper Fig. 2: "CIF image buffer ...
//! connecting CIF with the FPGA internal bus"; "CIF waits for data bursts
//! to be stored in the image buffer").
//!
//! Transaction-level: a burst of N words costs `setup + N/words_per_cycle`
//! bus cycles. The host (or SpaceWire transcoder) fills the CIF image
//! buffer through this model, and drains the LCD image buffer likewise.

use crate::fabric::clock::{ClockDomain, SimTime};

/// Bus timing parameters.
#[derive(Clone, Copy, Debug)]
pub struct BusConfig {
    pub clock: ClockDomain,
    /// Arbitration + address phase overhead per burst.
    pub setup_cycles: u64,
    /// Data beats per cycle (1 for a single 32-bit AHB-style bus).
    pub words_per_cycle: f64,
    /// Maximum burst length in words (longer transfers are split).
    pub max_burst: usize,
}

impl BusConfig {
    /// 50 MHz single-beat AHB-style bus with 16-word bursts.
    pub fn default_50mhz() -> BusConfig {
        BusConfig {
            clock: ClockDomain::new(50.0e6),
            setup_cycles: 4,
            words_per_cycle: 1.0,
            max_burst: 16,
        }
    }
}

/// Stateless burst-cost calculator + cumulative traffic statistics.
#[derive(Clone, Debug)]
pub struct Bus {
    pub cfg: BusConfig,
    pub words_transferred: u64,
    pub bursts: u64,
    pub busy_cycles: u64,
}

impl Bus {
    pub fn new(cfg: BusConfig) -> Bus {
        Bus {
            cfg,
            words_transferred: 0,
            bursts: 0,
            busy_cycles: 0,
        }
    }

    /// Cycles to move `n_words` (split into max_burst chunks).
    pub fn burst_cycles(&self, n_words: usize) -> u64 {
        if n_words == 0 {
            return 0;
        }
        let n_bursts = n_words.div_ceil(self.cfg.max_burst) as u64;
        let data_cycles =
            (n_words as f64 / self.cfg.words_per_cycle).ceil() as u64;
        n_bursts * self.cfg.setup_cycles + data_cycles
    }

    /// Account a transfer and return its duration.
    pub fn transfer(&mut self, n_words: usize) -> SimTime {
        let cycles = self.burst_cycles(n_words);
        self.words_transferred += n_words as u64;
        self.bursts += n_words.div_ceil(self.cfg.max_burst) as u64;
        self.busy_cycles += cycles;
        self.cfg.clock.cycles(cycles)
    }

    /// Achieved bandwidth in bytes/s for a transfer of `n_words`.
    pub fn effective_bandwidth(&self, n_words: usize) -> f64 {
        let t = self.cfg.clock.cycles(self.burst_cycles(n_words)).as_secs();
        if t == 0.0 {
            0.0
        } else {
            n_words as f64 * 4.0 / t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_words_is_free() {
        let bus = Bus::new(BusConfig::default_50mhz());
        assert_eq!(bus.burst_cycles(0), 0);
    }

    #[test]
    fn single_burst_cost() {
        let bus = Bus::new(BusConfig::default_50mhz());
        // 16 words: 4 setup + 16 data.
        assert_eq!(bus.burst_cycles(16), 20);
    }

    #[test]
    fn long_transfer_splits_into_bursts() {
        let bus = Bus::new(BusConfig::default_50mhz());
        // 33 words = 3 bursts -> 12 setup + 33 data.
        assert_eq!(bus.burst_cycles(33), 45);
    }

    #[test]
    fn transfer_accumulates_stats() {
        let mut bus = Bus::new(BusConfig::default_50mhz());
        let t = bus.transfer(32);
        assert_eq!(bus.words_transferred, 32);
        assert_eq!(bus.bursts, 2);
        assert_eq!(t, bus.cfg.clock.cycles(8 + 32));
    }

    #[test]
    fn bandwidth_approaches_wire_speed_for_large_bursts() {
        let bus = Bus::new(BusConfig::default_50mhz());
        let bw = bus.effective_bandwidth(1 << 20);
        // 50 MHz * 4 B = 200 MB/s wire; setup amortizes to ~80 %+.
        assert!(bw > 0.75 * 200.0e6, "bw {bw}");
        assert!(bw <= 200.0e6);
    }
}
