//! FPGA internal 32-bit bus model (paper Fig. 2: "CIF image buffer ...
//! connecting CIF with the FPGA internal bus"; "CIF waits for data bursts
//! to be stored in the image buffer").
//!
//! Transaction-level: a burst of N words costs `setup + N/words_per_cycle`
//! bus cycles. The host (or SpaceWire transcoder) fills the CIF image
//! buffer through this model, and drains the LCD image buffer likewise.

use crate::fabric::clock::{ClockDomain, SimTime};

/// Bus timing parameters.
#[derive(Clone, Copy, Debug)]
pub struct BusConfig {
    pub clock: ClockDomain,
    /// Arbitration + address phase overhead per burst.
    pub setup_cycles: u64,
    /// Data beats per cycle (1 for a single 32-bit AHB-style bus).
    pub words_per_cycle: f64,
    /// Maximum burst length in words (longer transfers are split).
    pub max_burst: usize,
}

impl BusConfig {
    /// 50 MHz single-beat AHB-style bus with 16-word bursts.
    pub fn default_50mhz() -> BusConfig {
        BusConfig {
            clock: ClockDomain::new(50.0e6),
            setup_cycles: 4,
            words_per_cycle: 1.0,
            max_burst: 16,
        }
    }
}

/// Stateless burst-cost calculator + cumulative traffic statistics.
#[derive(Clone, Debug)]
pub struct Bus {
    pub cfg: BusConfig,
    pub words_transferred: u64,
    pub bursts: u64,
    pub busy_cycles: u64,
}

impl Bus {
    pub fn new(cfg: BusConfig) -> Bus {
        Bus {
            cfg,
            words_transferred: 0,
            bursts: 0,
            busy_cycles: 0,
        }
    }

    /// Cycles to move `n_words` (split into max_burst chunks).
    pub fn burst_cycles(&self, n_words: usize) -> u64 {
        if n_words == 0 {
            return 0;
        }
        let n_bursts = n_words.div_ceil(self.cfg.max_burst) as u64;
        let data_cycles =
            (n_words as f64 / self.cfg.words_per_cycle).ceil() as u64;
        n_bursts * self.cfg.setup_cycles + data_cycles
    }

    /// Account a transfer and return its duration.
    pub fn transfer(&mut self, n_words: usize) -> SimTime {
        let cycles = self.burst_cycles(n_words);
        self.words_transferred += n_words as u64;
        self.bursts += n_words.div_ceil(self.cfg.max_burst) as u64;
        self.busy_cycles += cycles;
        self.cfg.clock.cycles(cycles)
    }

    /// Achieved bandwidth in bytes/s for a transfer of `n_words`.
    pub fn effective_bandwidth(&self, n_words: usize) -> f64 {
        let t = self.cfg.clock.cycles(self.burst_cycles(n_words)).as_secs();
        if t == 0.0 {
            0.0
        } else {
            n_words as f64 * 4.0 / t
        }
    }
}

/// A granted host-bus window: `[start, end)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BusGrant {
    pub start: SimTime,
    pub end: SimTime,
}

impl BusGrant {
    /// Time spent queued before the grant opened.
    pub fn wait(&self, requested_at: SimTime) -> SimTime {
        self.start.saturating_sub(requested_at)
    }
}

/// Shared host-bus arbiter (ISSUE 8): the framing processor muxes all
/// per-node CIF/LCD pixel links over a small number of host-side
/// channels, so concurrent transfers queue for grants instead of
/// scaling for free. Purely virtual-time and deterministic: requests
/// are granted FIFO in request order onto the earliest-free channel.
///
/// `channels >= concurrent requesters` degenerates to zero waiting,
/// which is how the default (uncontended) topology stays bit-exact
/// with the pre-fleet stream.
#[derive(Clone, Debug)]
pub struct HostBus {
    /// Next-free time per host channel.
    free_at: Vec<SimTime>,
    /// Cumulative grants issued.
    pub grants: u64,
    /// Cumulative time requests spent queued.
    pub queued: SimTime,
}

impl HostBus {
    pub fn new(channels: usize) -> HostBus {
        HostBus {
            free_at: vec![SimTime::ZERO; channels.max(1)],
            grants: 0,
            queued: SimTime::ZERO,
        }
    }

    pub fn channels(&self) -> usize {
        self.free_at.len()
    }

    /// Earliest instant any channel could open a new grant.
    pub fn earliest_free(&self) -> SimTime {
        self.free_at.iter().copied().min().unwrap_or(SimTime::ZERO)
    }

    /// Request the bus at `now` for `duration`; the grant opens on the
    /// earliest-free channel, no sooner than `now`.
    pub fn request(&mut self, now: SimTime, duration: SimTime) -> BusGrant {
        let c = self
            .free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, t)| **t)
            .map(|(i, _)| i)
            .unwrap_or(0);
        let start = self.free_at[c].max(now);
        let end = start + duration;
        self.free_at[c] = end;
        self.grants += 1;
        self.queued += start.saturating_sub(now);
        BusGrant { start, end }
    }

    /// Non-mutating estimate of the wait a request made at `now` would
    /// see — the earliest-finish-time scheduler's bus-grant term.
    pub fn projected_wait(&self, now: SimTime) -> SimTime {
        self.earliest_free().saturating_sub(now)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_words_is_free() {
        let bus = Bus::new(BusConfig::default_50mhz());
        assert_eq!(bus.burst_cycles(0), 0);
    }

    #[test]
    fn single_burst_cost() {
        let bus = Bus::new(BusConfig::default_50mhz());
        // 16 words: 4 setup + 16 data.
        assert_eq!(bus.burst_cycles(16), 20);
    }

    #[test]
    fn long_transfer_splits_into_bursts() {
        let bus = Bus::new(BusConfig::default_50mhz());
        // 33 words = 3 bursts -> 12 setup + 33 data.
        assert_eq!(bus.burst_cycles(33), 45);
    }

    #[test]
    fn transfer_accumulates_stats() {
        let mut bus = Bus::new(BusConfig::default_50mhz());
        let t = bus.transfer(32);
        assert_eq!(bus.words_transferred, 32);
        assert_eq!(bus.bursts, 2);
        assert_eq!(t, bus.cfg.clock.cycles(8 + 32));
    }

    #[test]
    fn bandwidth_approaches_wire_speed_for_large_bursts() {
        let bus = Bus::new(BusConfig::default_50mhz());
        let bw = bus.effective_bandwidth(1 << 20);
        // 50 MHz * 4 B = 200 MB/s wire; setup amortizes to ~80 %+.
        assert!(bw > 0.75 * 200.0e6, "bw {bw}");
        assert!(bw <= 200.0e6);
    }

    #[test]
    fn single_channel_serializes_overlapping_grants() {
        let mut bus = HostBus::new(1);
        let w = SimTime::from_ms(10.0);
        let g0 = bus.request(SimTime::ZERO, w);
        let g1 = bus.request(SimTime::ZERO, w);
        assert_eq!(g0.start, SimTime::ZERO);
        assert_eq!(g0.end, w);
        assert_eq!(g1.start, w, "second grant queues behind the first");
        assert_eq!(g1.wait(SimTime::ZERO), w);
        assert_eq!(bus.queued, w);
        assert_eq!(bus.grants, 2);
    }

    #[test]
    fn extra_channels_grant_in_parallel() {
        let mut bus = HostBus::new(2);
        let w = SimTime::from_ms(10.0);
        let g0 = bus.request(SimTime::ZERO, w);
        let g1 = bus.request(SimTime::ZERO, w);
        assert_eq!(g0.start, SimTime::ZERO);
        assert_eq!(g1.start, SimTime::ZERO, "two channels, no queueing");
        assert_eq!(bus.queued, SimTime::ZERO);
        // Third request waits for the first channel to free.
        let g2 = bus.request(SimTime::ZERO, w);
        assert_eq!(g2.start, w);
    }

    #[test]
    fn idle_gaps_do_not_backdate_grants() {
        let mut bus = HostBus::new(1);
        let w = SimTime::from_ms(5.0);
        bus.request(SimTime::ZERO, w);
        let late = SimTime::from_ms(50.0);
        let g = bus.request(late, w);
        assert_eq!(g.start, late, "grants never open before the request");
        assert_eq!(bus.projected_wait(late), SimTime::ZERO);
    }

    #[test]
    fn projected_wait_matches_next_grant() {
        let mut bus = HostBus::new(1);
        let w = SimTime::from_ms(8.0);
        bus.request(SimTime::ZERO, w);
        let est = bus.projected_wait(SimTime::from_ms(2.0));
        let g = bus.request(SimTime::from_ms(2.0), w);
        assert_eq!(g.wait(SimTime::from_ms(2.0)), est);
    }
}
