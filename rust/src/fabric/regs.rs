//! Control & status registers of the CIF/LCD interface (paper §III-A):
//! "control registers ... are written at runtime to configure the frame
//! dimensions and the pixel bit-width. Moreover, status registers are
//! updated at runtime when an input/output frame is transmitted/received
//! ... such as the CRC results of both directions and the total number of
//! frames transmitted/received."
//!
//! The register file is addressable like the real memory-mapped block so
//! the supervisor-side control software (coordinator) reads/writes it the
//! way the GR716 would over the FPGA's internal bus.

use crate::error::{Error, Result};
use crate::util::image::PixelFormat;

/// Word addresses of the register block (one per 32-bit register).
pub mod addr {
    pub const CTRL_WIDTH: u32 = 0x00;
    pub const CTRL_HEIGHT: u32 = 0x01;
    pub const CTRL_BPP: u32 = 0x02;
    pub const CTRL_ENABLE: u32 = 0x03;
    pub const STAT_FRAMES_TX: u32 = 0x10;
    pub const STAT_FRAMES_RX: u32 = 0x11;
    pub const STAT_CRC_LAST_TX: u32 = 0x12;
    pub const STAT_CRC_LAST_RX: u32 = 0x13;
    pub const STAT_CRC_OK: u32 = 0x14;
    pub const STAT_CRC_ERR: u32 = 0x15;
    pub const STAT_FIFO_HIGH_WATER: u32 = 0x16;
}

/// The CIF/LCD register block.
#[derive(Clone, Debug, Default)]
pub struct InterfaceRegs {
    pub width: u32,
    pub height: u32,
    pub bpp: u32,
    pub enabled: bool,
    pub frames_tx: u32,
    pub frames_rx: u32,
    pub crc_last_tx: u32,
    pub crc_last_rx: u32,
    pub crc_ok: u32,
    pub crc_err: u32,
    pub fifo_high_water: u32,
}

impl InterfaceRegs {
    /// Configure geometry (host writes the control registers).
    pub fn configure(&mut self, width: usize, height: usize, format: PixelFormat) {
        self.width = width as u32;
        self.height = height as u32;
        self.bpp = format.bits();
        self.enabled = true;
    }

    pub fn format(&self) -> Result<PixelFormat> {
        match self.bpp {
            8 => Ok(PixelFormat::Bpp8),
            16 => Ok(PixelFormat::Bpp16),
            24 => Ok(PixelFormat::Bpp24),
            other => Err(Error::Config(format!("bpp register holds {other}"))),
        }
    }

    /// Memory-mapped read.
    pub fn read(&self, a: u32) -> Result<u32> {
        use addr::*;
        Ok(match a {
            CTRL_WIDTH => self.width,
            CTRL_HEIGHT => self.height,
            CTRL_BPP => self.bpp,
            CTRL_ENABLE => self.enabled as u32,
            STAT_FRAMES_TX => self.frames_tx,
            STAT_FRAMES_RX => self.frames_rx,
            STAT_CRC_LAST_TX => self.crc_last_tx,
            STAT_CRC_LAST_RX => self.crc_last_rx,
            STAT_CRC_OK => self.crc_ok,
            STAT_CRC_ERR => self.crc_err,
            STAT_FIFO_HIGH_WATER => self.fifo_high_water,
            other => {
                return Err(Error::Config(format!(
                    "read of unmapped register {other:#x}"
                )))
            }
        })
    }

    /// Memory-mapped write; status registers are read-only to the bus.
    pub fn write(&mut self, a: u32, v: u32) -> Result<()> {
        use addr::*;
        match a {
            CTRL_WIDTH => self.width = v,
            CTRL_HEIGHT => self.height = v,
            CTRL_BPP => {
                if !matches!(v, 8 | 16 | 24) {
                    return Err(Error::Config(format!("bpp {v} unsupported")));
                }
                self.bpp = v;
            }
            CTRL_ENABLE => self.enabled = v != 0,
            STAT_FRAMES_TX..=STAT_FIFO_HIGH_WATER => {
                return Err(Error::Config(format!(
                    "write to read-only status register {a:#x}"
                )));
            }
            other => {
                return Err(Error::Config(format!(
                    "write to unmapped register {other:#x}"
                )))
            }
        }
        Ok(())
    }

    /// Hardware-side status update after a transmitted frame.
    pub fn note_tx(&mut self, crc: u16) {
        self.frames_tx = self.frames_tx.wrapping_add(1);
        self.crc_last_tx = crc as u32;
    }

    /// Hardware-side status update after a received frame.
    pub fn note_rx(&mut self, crc: u16, ok: bool) {
        self.frames_rx = self.frames_rx.wrapping_add(1);
        self.crc_last_rx = crc as u32;
        if ok {
            self.crc_ok = self.crc_ok.wrapping_add(1);
        } else {
            self.crc_err = self.crc_err.wrapping_add(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configure_then_read_back() {
        let mut r = InterfaceRegs::default();
        r.configure(1024, 768, PixelFormat::Bpp16);
        assert_eq!(r.read(addr::CTRL_WIDTH).unwrap(), 1024);
        assert_eq!(r.read(addr::CTRL_HEIGHT).unwrap(), 768);
        assert_eq!(r.read(addr::CTRL_BPP).unwrap(), 16);
        assert_eq!(r.format().unwrap(), PixelFormat::Bpp16);
    }

    #[test]
    fn status_registers_read_only() {
        let mut r = InterfaceRegs::default();
        assert!(r.write(addr::STAT_FRAMES_TX, 5).is_err());
        assert!(r.write(addr::STAT_CRC_ERR, 1).is_err());
    }

    #[test]
    fn bpp_write_validated() {
        let mut r = InterfaceRegs::default();
        assert!(r.write(addr::CTRL_BPP, 12).is_err());
        r.write(addr::CTRL_BPP, 24).unwrap();
        assert_eq!(r.format().unwrap(), PixelFormat::Bpp24);
    }

    #[test]
    fn unmapped_addresses_rejected() {
        let mut r = InterfaceRegs::default();
        assert!(r.read(0x99).is_err());
        assert!(r.write(0x99, 0).is_err());
    }

    #[test]
    fn tx_rx_counters_and_crc_history() {
        let mut r = InterfaceRegs::default();
        r.note_tx(0xAAAA);
        r.note_tx(0xBBBB);
        r.note_rx(0xBBBB, true);
        r.note_rx(0x1234, false);
        assert_eq!(r.read(addr::STAT_FRAMES_TX).unwrap(), 2);
        assert_eq!(r.read(addr::STAT_FRAMES_RX).unwrap(), 2);
        assert_eq!(r.read(addr::STAT_CRC_LAST_TX).unwrap(), 0xBBBB);
        assert_eq!(r.read(addr::STAT_CRC_OK).unwrap(), 1);
        assert_eq!(r.read(addr::STAT_CRC_ERR).unwrap(), 1);
    }

    #[test]
    fn default_format_is_error_until_configured() {
        let r = InterfaceRegs::default();
        assert!(r.format().is_err());
    }
}
