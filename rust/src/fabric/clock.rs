//! Clock domains and simulated time.
//!
//! Simulated time is integer picoseconds — deterministic, no float drift
//! when accumulating billions of cycles, and fine enough to resolve the
//! paper's fastest clock (600 MHz SHAVE => 1667 ps period).

use std::ops::{Add, AddAssign, Sub};

/// Absolute or relative simulated time in picoseconds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn from_secs(s: f64) -> SimTime {
        SimTime((s * 1e12).round() as u64)
    }

    pub fn from_ms(ms: f64) -> SimTime {
        SimTime::from_secs(ms * 1e-3)
    }

    pub fn from_us(us: f64) -> SimTime {
        SimTime::from_secs(us * 1e-6)
    }

    pub fn as_secs(self) -> f64 {
        self.0 as f64 * 1e-12
    }

    pub fn as_ms(self) -> f64 {
        self.0 as f64 * 1e-9
    }

    pub fn as_us(self) -> f64 {
        self.0 as f64 * 1e-6
    }

    pub fn max(self, other: SimTime) -> SimTime {
        SimTime(self.0.max(other.0))
    }

    /// Events per second for a per-event duration (`1 / as_secs()`),
    /// `0.0` for a zero duration — degenerate latencies must not leak
    /// non-finite values into reports or `util::json` output.
    pub fn rate_hz(self) -> f64 {
        if self.0 == 0 {
            0.0
        } else {
            1.0 / self.as_secs()
        }
    }

    pub fn saturating_sub(self, other: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(other.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl std::fmt::Display for SimTime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", crate::util::fmt_time(self.as_secs()))
    }
}

/// One clock domain (the CIF and LCD modules may run on different clocks;
/// the paper's FIFOs are CDC-capable for exactly this reason).
#[derive(Clone, Copy, Debug)]
pub struct ClockDomain {
    pub freq_hz: f64,
    period_ps: u64,
}

impl ClockDomain {
    pub fn new(freq_hz: f64) -> ClockDomain {
        assert!(freq_hz > 0.0);
        ClockDomain {
            freq_hz,
            period_ps: (1e12 / freq_hz).round() as u64,
        }
    }

    pub fn period(&self) -> SimTime {
        SimTime(self.period_ps)
    }

    /// Duration of `n` cycles of this clock.
    pub fn cycles(&self, n: u64) -> SimTime {
        SimTime(self.period_ps * n)
    }

    /// Whole cycles elapsed at time `t` (floor).
    pub fn cycles_at(&self, t: SimTime) -> u64 {
        t.0 / self.period_ps
    }

    /// Earliest clock edge at or after `t`.
    pub fn next_edge(&self, t: SimTime) -> SimTime {
        let rem = t.0 % self.period_ps;
        if rem == 0 {
            t
        } else {
            SimTime(t.0 + self.period_ps - rem)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_duration_at_50mhz() {
        let clk = ClockDomain::new(50.0e6);
        assert_eq!(clk.period(), SimTime(20_000)); // 20 ns
        // 1 MPixel at 1 px/cycle = ~21 ms (paper: 1024x1024 in 20.9 ms).
        let t = clk.cycles(1024 * 1024);
        assert!((t.as_ms() - 20.97).abs() < 0.01, "{}", t.as_ms());
    }

    #[test]
    fn shave_clock_resolved() {
        let clk = ClockDomain::new(600.0e6);
        assert_eq!(clk.period(), SimTime(1667));
    }

    #[test]
    fn next_edge_snaps_up() {
        let clk = ClockDomain::new(100.0e6); // 10 ns
        assert_eq!(clk.next_edge(SimTime(0)), SimTime(0));
        assert_eq!(clk.next_edge(SimTime(1)), SimTime(10_000));
        assert_eq!(clk.next_edge(SimTime(10_000)), SimTime(10_000));
        assert_eq!(clk.next_edge(SimTime(10_001)), SimTime(20_000));
    }

    #[test]
    fn simtime_conversions() {
        assert_eq!(SimTime::from_ms(21.0).as_ms(), 21.0);
        assert!((SimTime::from_us(3.5).as_secs() - 3.5e-6).abs() < 1e-15);
    }

    #[test]
    fn rate_hz_finite_even_for_zero_duration() {
        assert_eq!(SimTime::ZERO.rate_hz(), 0.0);
        assert!((SimTime::from_ms(50.0).rate_hz() - 20.0).abs() < 1e-9);
        assert!(SimTime::ZERO.rate_hz().is_finite());
    }

    #[test]
    fn simtime_arithmetic() {
        let a = SimTime(100) + SimTime(50);
        assert_eq!(a, SimTime(150));
        assert_eq!(a - SimTime(150), SimTime::ZERO);
        assert_eq!(SimTime(10).saturating_sub(SimTime(20)), SimTime::ZERO);
        assert_eq!(SimTime(10).max(SimTime(20)), SimTime(20));
    }
}
