//! CRC-16/XMODEM — the frame integrity check the paper's CIF appends to
//! the last line of every transmitted frame (§III-A).
//!
//! Parameters: poly 0x1021, init 0x0000, no reflection, xorout 0x0000.
//! Two implementations: bitwise (the HDL's serial LFSR) and table-driven
//! (the hot-path version); tests pin them to each other and to the
//! published check value.

/// Table-driven CRC-16/XMODEM engine.
#[derive(Clone, Debug)]
pub struct Crc16Xmodem {
    state: u16,
}

const POLY: u16 = 0x1021;

static TABLE: once_cell::sync::Lazy<[u16; 256]> = once_cell::sync::Lazy::new(|| {
    let mut table = [0u16; 256];
    for (i, entry) in table.iter_mut().enumerate() {
        let mut crc = (i as u16) << 8;
        for _ in 0..8 {
            crc = if crc & 0x8000 != 0 {
                (crc << 1) ^ POLY
            } else {
                crc << 1
            };
        }
        *entry = crc;
    }
    table
});

/// Slicing-by-16 tables: SLICE[k][b] = CRC of byte `b` followed by k zero
/// bytes. Lets `update` consume 16 bytes per iteration: only the first
/// two lookups mix with the running CRC, the other fourteen are fully
/// independent loads, so the serial dependency chain shrinks to one XOR
/// reduction per 16-byte block (vs one per 4 bytes with slicing-by-4).
static SLICE: once_cell::sync::Lazy<[[u16; 256]; 16]> = once_cell::sync::Lazy::new(|| {
    let t0 = &*TABLE;
    let mut s = [[0u16; 256]; 16];
    s[0] = *t0;
    for k in 1..16 {
        for b in 0..256 {
            // Append one zero byte to the k-1 variant.
            let prev = s[k - 1][b];
            s[k][b] = (prev << 8) ^ t0[(prev >> 8) as usize];
        }
    }
    s
});

/// Slicing-by-32 tables for the `KernelBackend::Simd` tier: same
/// construction as [`struct@SLICE`] extended to 32 zero-byte shifts, so
/// one iteration consumes a 32-byte block — thirty fully independent
/// table loads per serial XOR reduction (twice the ILP of the
/// Optimized tier's 16-byte blocks). 16 KiB, built once on first use.
static SLICE32: once_cell::sync::Lazy<Box<[[u16; 256]; 32]>> =
    once_cell::sync::Lazy::new(|| {
        let t0 = &*TABLE;
        let mut s = Box::new([[0u16; 256]; 32]);
        s[0] = *t0;
        for k in 1..32 {
            for b in 0..256 {
                let prev = s[k - 1][b];
                s[k][b] = (prev << 8) ^ t0[(prev >> 8) as usize];
            }
        }
        s
    });

/// True when `SPACECODESIGN_BACKEND=simd` selects the explicit-SIMD
/// tier; cached once (the CRC sits below the dispatched call signatures
/// — `iface::signals::payload_crc` and the drivers call it with no
/// backend in scope — so the tier is an engine-level switch here, like
/// the env var itself). Both engines are value-identical by
/// construction; the pins in `tests/kernel_equivalence.rs` hold on
/// either path.
fn simd_tier() -> bool {
    static SIMD: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *SIMD.get_or_init(|| crate::KernelBackend::from_env() == crate::KernelBackend::Simd)
}

impl Default for Crc16Xmodem {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc16Xmodem {
    pub fn new() -> Crc16Xmodem {
        Crc16Xmodem { state: 0 }
    }

    #[inline(always)]
    fn step_t(table: &[u16; 256], crc: u16, b: u8) -> u16 {
        let idx = ((crc >> 8) ^ b as u16) & 0xFF;
        (crc << 8) ^ table[idx as usize]
    }

    #[inline]
    fn step(crc: u16, b: u8) -> u16 {
        Self::step_t(&TABLE, crc, b)
    }

    /// One 16-byte block: crc' = S15[hi^b0] ^ S14[lo^b1] ^ S13[b2] ^ ...
    /// ^ S0[b15]. Sixteen independent table loads, one XOR tree.
    #[inline(always)]
    fn step_block16(sl: &[[u16; 256]; 16], crc: u16, b: &[u8; 16]) -> u16 {
        let mut acc = sl[15][((crc >> 8) as u8 ^ b[0]) as usize]
            ^ sl[14][((crc & 0xFF) as u8 ^ b[1]) as usize];
        for j in 2..16 {
            acc ^= sl[15 - j][b[j] as usize];
        }
        acc
    }

    /// One 32-byte block through the widened tables — the Simd-tier
    /// inner step: two crc-mixed lookups, thirty independent ones.
    #[inline(always)]
    fn step_block32(sl: &[[u16; 256]; 32], crc: u16, b: &[u8; 32]) -> u16 {
        let mut acc = sl[31][((crc >> 8) as u8 ^ b[0]) as usize]
            ^ sl[30][((crc & 0xFF) as u8 ^ b[1]) as usize];
        for j in 2..32 {
            acc ^= sl[31 - j][b[j] as usize];
        }
        acc
    }

    pub fn update(&mut self, data: &[u8]) {
        if simd_tier() {
            self.update_simd(data);
            return;
        }
        let sl = &*SLICE;
        let mut crc = self.state;
        let mut blocks = data.chunks_exact(16);
        for blk in &mut blocks {
            let blk: &[u8; 16] = blk.try_into().expect("chunks_exact(16)");
            crc = Self::step_block16(sl, crc, blk);
        }
        let table = &*TABLE;
        for &b in blocks.remainder() {
            crc = Self::step_t(table, crc, b);
        }
        self.state = crc;
    }

    /// Explicit Simd-tier byte path: 32-byte slicing blocks, 16-byte
    /// block for the next remainder class, scalar for the last <16.
    /// Value-identical to [`Crc16Xmodem::update`] for every input.
    pub fn update_simd(&mut self, data: &[u8]) {
        let sl32 = &*SLICE32;
        let mut crc = self.state;
        let mut blocks = data.chunks_exact(32);
        for blk in &mut blocks {
            let blk: &[u8; 32] = blk.try_into().expect("chunks_exact(32)");
            crc = Self::step_block32(sl32, crc, blk);
        }
        let mut rest = blocks.remainder().chunks_exact(16);
        let sl = &*SLICE;
        for blk in &mut rest {
            let blk: &[u8; 16] = blk.try_into().expect("chunks_exact(16)");
            crc = Self::step_block16(sl, crc, blk);
        }
        let table = &*TABLE;
        for &b in rest.remainder() {
            crc = Self::step_t(table, crc, b);
        }
        self.state = crc;
    }

    /// Feed one pixel, honoring its wire width (8/16/24 bpp -> 1/2/3
    /// bytes, most-significant byte first, as the serial HDL shifts it).
    #[inline]
    pub fn update_pixel(&mut self, pixel: u32, bits: u32) {
        debug_assert!(matches!(bits, 8 | 16 | 24));
        let mut crc = self.state;
        if bits == 24 {
            crc = Self::step(crc, (pixel >> 16) as u8);
        }
        if bits >= 16 {
            crc = Self::step(crc, (pixel >> 8) as u8);
        }
        crc = Self::step(crc, pixel as u8);
        self.state = crc;
    }

    /// Bulk pixel-stream CRC (the Tx/Rx hot path): pixels are serialized
    /// into 16-byte stack blocks and pushed through the slicing-by-16
    /// engine; one table deref, one state load/store for the stream.
    pub fn update_pixels(&mut self, pixels: &[u32], bits: u32) {
        debug_assert!(matches!(bits, 8 | 16 | 24));
        if simd_tier() {
            self.update_pixels_simd(pixels, bits);
            return;
        }
        let table = &*TABLE; // hoist the Lazy deref out of the loop
        let sl = &*SLICE;
        let mut crc = self.state;
        let mut buf = [0u8; 48];
        match bits {
            8 => {
                let mut chunks = pixels.chunks_exact(16);
                for c in &mut chunks {
                    for (d, &px) in buf[..16].iter_mut().zip(c) {
                        *d = px as u8;
                    }
                    let blk: &[u8; 16] = buf[..16].try_into().expect("16-byte block");
                    crc = Self::step_block16(sl, crc, blk);
                }
                for &px in chunks.remainder() {
                    crc = Self::step_t(table, crc, px as u8);
                }
            }
            16 => {
                let mut chunks = pixels.chunks_exact(8);
                for c in &mut chunks {
                    for (d, &px) in buf.chunks_exact_mut(2).zip(c) {
                        d[0] = (px >> 8) as u8;
                        d[1] = px as u8;
                    }
                    let blk: &[u8; 16] = buf[..16].try_into().expect("16-byte block");
                    crc = Self::step_block16(sl, crc, blk);
                }
                for &px in chunks.remainder() {
                    crc = Self::step_t(table, crc, (px >> 8) as u8);
                    crc = Self::step_t(table, crc, px as u8);
                }
            }
            _ => {
                // 24 bpp: 16 pixels = 48 bytes = three 16-byte blocks.
                let mut chunks = pixels.chunks_exact(16);
                for c in &mut chunks {
                    for (d, &px) in buf.chunks_exact_mut(3).zip(c) {
                        d[0] = (px >> 16) as u8;
                        d[1] = (px >> 8) as u8;
                        d[2] = px as u8;
                    }
                    for blk in buf.chunks_exact(16) {
                        let blk: &[u8; 16] = blk.try_into().expect("16-byte block");
                        crc = Self::step_block16(sl, crc, blk);
                    }
                }
                for &px in chunks.remainder() {
                    crc = Self::step_t(table, crc, (px >> 16) as u8);
                    crc = Self::step_t(table, crc, (px >> 8) as u8);
                    crc = Self::step_t(table, crc, px as u8);
                }
            }
        }
        self.state = crc;
    }

    /// Simd-tier pixel-stream path: pixels are serialized into 32-byte
    /// (8/16 bpp) or 96-byte (24 bpp) stack rounds pushed through the
    /// slicing-by-32 engine. Value-identical to the per-pixel feed.
    pub fn update_pixels_simd(&mut self, pixels: &[u32], bits: u32) {
        debug_assert!(matches!(bits, 8 | 16 | 24));
        let table = &*TABLE;
        let sl32 = &*SLICE32;
        let mut crc = self.state;
        let mut buf = [0u8; 96];
        match bits {
            8 => {
                let mut chunks = pixels.chunks_exact(32);
                for c in &mut chunks {
                    for (d, &px) in buf[..32].iter_mut().zip(c) {
                        *d = px as u8;
                    }
                    let blk: &[u8; 32] = buf[..32].try_into().expect("32-byte block");
                    crc = Self::step_block32(sl32, crc, blk);
                }
                for &px in chunks.remainder() {
                    crc = Self::step_t(table, crc, px as u8);
                }
            }
            16 => {
                let mut chunks = pixels.chunks_exact(16);
                for c in &mut chunks {
                    for (d, &px) in buf.chunks_exact_mut(2).zip(c) {
                        d[0] = (px >> 8) as u8;
                        d[1] = px as u8;
                    }
                    let blk: &[u8; 32] = buf[..32].try_into().expect("32-byte block");
                    crc = Self::step_block32(sl32, crc, blk);
                }
                for &px in chunks.remainder() {
                    crc = Self::step_t(table, crc, (px >> 8) as u8);
                    crc = Self::step_t(table, crc, px as u8);
                }
            }
            _ => {
                // 24 bpp: 32 pixels = 96 bytes = three 32-byte blocks.
                let mut chunks = pixels.chunks_exact(32);
                for c in &mut chunks {
                    for (d, &px) in buf.chunks_exact_mut(3).zip(c) {
                        d[0] = (px >> 16) as u8;
                        d[1] = (px >> 8) as u8;
                        d[2] = px as u8;
                    }
                    for blk in buf.chunks_exact(32) {
                        let blk: &[u8; 32] = blk.try_into().expect("32-byte block");
                        crc = Self::step_block32(sl32, crc, blk);
                    }
                }
                for &px in chunks.remainder() {
                    crc = Self::step_t(table, crc, (px >> 16) as u8);
                    crc = Self::step_t(table, crc, (px >> 8) as u8);
                    crc = Self::step_t(table, crc, px as u8);
                }
            }
        }
        self.state = crc;
    }

    pub fn finish(&self) -> u16 {
        self.state
    }

    /// One-shot convenience over a byte slice.
    pub fn checksum(data: &[u8]) -> u16 {
        let mut c = Crc16Xmodem::new();
        c.update(data);
        c.finish()
    }

    /// One-shot CRC over a pixel line (ISSUE 9): the per-line erasure
    /// locator of the FEC framing. Same serialization as the frame CRC
    /// (`update_pixels`, MSB-first per pixel), restricted to one line,
    /// so the FPGA computes it with the same shift logic it already
    /// has — one extra register per line in flight.
    pub fn checksum_pixels(pixels: &[u32], bits: u32) -> u16 {
        let mut c = Crc16Xmodem::new();
        c.update_pixels(pixels, bits);
        c.finish()
    }

    /// One-shot over the explicit Simd-tier slicing-by-32 engine.
    pub fn checksum_simd(data: &[u8]) -> u16 {
        let mut c = Crc16Xmodem::new();
        c.update_simd(data);
        c.finish()
    }

    /// Bit-serial reference implementation (the HDL LFSR); used by tests
    /// to pin the table-driven version.
    pub fn checksum_bitwise(data: &[u8]) -> u16 {
        let mut crc: u16 = 0;
        for &b in data {
            crc ^= (b as u16) << 8;
            for _ in 0..8 {
                crc = if crc & 0x8000 != 0 {
                    (crc << 1) ^ POLY
                } else {
                    crc << 1
                };
            }
        }
        crc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn published_check_value() {
        // CRC-16/XMODEM("123456789") = 0x31C3 (CRC catalogue check value).
        assert_eq!(Crc16Xmodem::checksum(b"123456789"), 0x31C3);
    }

    #[test]
    fn empty_input_is_zero() {
        assert_eq!(Crc16Xmodem::checksum(b""), 0x0000);
    }

    #[test]
    fn line_checksum_matches_byte_serialization() {
        // 8bpp pixels serialize one byte each, so the line CRC equals
        // the catalogue check value over the same bytes.
        let pixels: Vec<u32> = b"123456789".iter().map(|&b| b as u32).collect();
        assert_eq!(Crc16Xmodem::checksum_pixels(&pixels, 8), 0x31C3);
        assert_eq!(Crc16Xmodem::checksum_pixels(&[], 16), 0x0000);
    }

    #[test]
    fn table_matches_bitwise_on_random_data() {
        let mut rng = Rng::new(42);
        // Lengths straddling the 16-byte slicing block: every remainder
        // class plus multi-block sizes.
        for len in [1usize, 7, 15, 16, 17, 31, 32, 33, 47, 48, 64, 1000] {
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            assert_eq!(
                Crc16Xmodem::checksum(&data),
                Crc16Xmodem::checksum_bitwise(&data),
                "len={len}"
            );
        }
    }

    #[test]
    fn incremental_equals_oneshot() {
        let data = b"the quick brown fox jumps over the lazy dog";
        let mut c = Crc16Xmodem::new();
        c.update(&data[..10]);
        c.update(&data[10..]);
        assert_eq!(c.finish(), Crc16Xmodem::checksum(data));
    }

    #[test]
    fn pixel_feeding_matches_byte_feeding() {
        // 16bpp pixel 0xABCD == bytes [0xAB, 0xCD].
        let mut a = Crc16Xmodem::new();
        a.update_pixel(0xABCD, 16);
        assert_eq!(a.finish(), Crc16Xmodem::checksum(&[0xAB, 0xCD]));

        let mut b = Crc16Xmodem::new();
        b.update_pixel(0x123456, 24);
        assert_eq!(b.finish(), Crc16Xmodem::checksum(&[0x12, 0x34, 0x56]));

        let mut c = Crc16Xmodem::new();
        c.update_pixel(0x7F, 8);
        assert_eq!(c.finish(), Crc16Xmodem::checksum(&[0x7F]));
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut rng = Rng::new(7);
        let mut data = vec![0u8; 512];
        rng.fill_bytes(&mut data);
        let clean = Crc16Xmodem::checksum(&data);
        for trial in 0..32 {
            let i = rng.range_usize(0, data.len() - 1);
            let bit = rng.range_usize(0, 7);
            data[i] ^= 1 << bit;
            assert_ne!(Crc16Xmodem::checksum(&data), clean, "trial {trial}");
            data[i] ^= 1 << bit; // restore
        }
    }
}

#[cfg(test)]
mod simd_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn slicing_by_32_matches_bitwise_every_remainder_class() {
        let mut rng = Rng::new(0x32);
        // Straddle the 32-byte block: <16 scalar tail, 16..31 (one
        // 16-block + tail), exact multiples, and long streams.
        for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 47, 48, 63, 64, 65, 96, 997] {
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            assert_eq!(
                Crc16Xmodem::checksum_simd(&data),
                Crc16Xmodem::checksum_bitwise(&data),
                "len={len}"
            );
        }
    }

    #[test]
    fn simd_incremental_equals_oneshot() {
        let mut rng = Rng::new(0x33);
        let mut data = vec![0u8; 200];
        rng.fill_bytes(&mut data);
        let mut c = Crc16Xmodem::new();
        c.update_simd(&data[..37]);
        c.update_simd(&data[37..]);
        assert_eq!(c.finish(), Crc16Xmodem::checksum(&data));
    }

    #[test]
    fn simd_pixel_path_matches_per_pixel_all_formats() {
        let mut rng = Rng::new(0x34);
        for bits in [8u32, 16, 24] {
            // Straddle the 32/16-pixel rounds of the simd serializer.
            for n in [0usize, 1, 7, 15, 16, 17, 31, 32, 33, 100] {
                let mask = (1u64 << bits) as u32 - 1;
                let pixels: Vec<u32> = (0..n).map(|_| rng.next_u32() & mask).collect();
                let mut a = Crc16Xmodem::new();
                a.update_pixels_simd(&pixels, bits);
                let mut b = Crc16Xmodem::new();
                for &px in &pixels {
                    b.update_pixel(px, bits);
                }
                assert_eq!(a.finish(), b.finish(), "bits={bits} n={n}");
            }
        }
    }
}

#[cfg(test)]
mod bulk_tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bulk_pixels_matches_per_pixel() {
        let mut rng = Rng::new(11);
        for bits in [8u32, 16, 24] {
            // Counts straddling the block sizes (16 px / 8 px per block)
            // so every remainder path is exercised.
            for n in [1usize, 5, 8, 15, 16, 17, 4093, 4096] {
                let mask = (1u64 << bits) as u32 - 1;
                let pixels: Vec<u32> =
                    (0..n).map(|_| rng.next_u32() & mask).collect();
                let mut a = Crc16Xmodem::new();
                a.update_pixels(&pixels, bits);
                let mut b = Crc16Xmodem::new();
                for &px in &pixels {
                    b.update_pixel(px, bits);
                }
                assert_eq!(a.finish(), b.finish(), "bits={bits} n={n}");
            }
        }
    }
}
