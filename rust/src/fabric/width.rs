//! Width-conversion FSMs (paper Fig. 2): the CIF FSM converts 32-bit bus
//! words into 8/16/24-bit wire pixels; the LCD FSM performs the inverse.
//!
//! Packing convention (little-endian within the word, matching the VHDL):
//! * 8 bpp : word = px0 | px1<<8 | px2<<16 | px3<<24  (4 px/word)
//! * 16 bpp: word = px0 | px1<<16                      (2 px/word)
//! * 24 bpp: word = px0 (bits 23:0; 31:24 unused)      (1 px/word)

use crate::error::{Error, Result};
use crate::util::image::PixelFormat;

/// 32-bit words -> pixels (CIF direction).
pub fn unpack_words(words: &[u32], format: PixelFormat, n_pixels: usize) -> Result<Vec<u32>> {
    let ppw = format.pixels_per_word();
    let needed = n_pixels.div_ceil(ppw);
    if words.len() < needed {
        return Err(Error::Geometry(format!(
            "{n_pixels} px at {}bpp need {needed} words, got {}",
            format.bits(),
            words.len()
        )));
    }
    let mut out = Vec::with_capacity(n_pixels);
    'outer: for &w in words {
        match format {
            PixelFormat::Bpp8 => {
                for i in 0..4 {
                    out.push((w >> (8 * i)) & 0xFF);
                    if out.len() == n_pixels {
                        break 'outer;
                    }
                }
            }
            PixelFormat::Bpp16 => {
                for i in 0..2 {
                    out.push((w >> (16 * i)) & 0xFFFF);
                    if out.len() == n_pixels {
                        break 'outer;
                    }
                }
            }
            PixelFormat::Bpp24 => {
                out.push(w & 0x00FF_FFFF);
                if out.len() == n_pixels {
                    break 'outer;
                }
            }
        }
    }
    Ok(out)
}

/// Pixels -> 32-bit words (LCD direction). The final partial word is
/// zero-padded in its unused lanes, as the HDL register would hold zeros.
pub fn pack_words(pixels: &[u32], format: PixelFormat) -> Result<Vec<u32>> {
    let max = format.max_value();
    if let Some(&bad) = pixels.iter().find(|&&p| p > max) {
        return Err(Error::Geometry(format!(
            "pixel {bad:#x} exceeds {}bpp",
            format.bits()
        )));
    }
    let ppw = format.pixels_per_word();
    let mut out = Vec::with_capacity(pixels.len().div_ceil(ppw));
    match format {
        PixelFormat::Bpp8 => {
            for chunk in pixels.chunks(4) {
                let mut w = 0u32;
                for (i, &p) in chunk.iter().enumerate() {
                    w |= p << (8 * i);
                }
                out.push(w);
            }
        }
        PixelFormat::Bpp16 => {
            for chunk in pixels.chunks(2) {
                let mut w = 0u32;
                for (i, &p) in chunk.iter().enumerate() {
                    w |= p << (16 * i);
                }
                out.push(w);
            }
        }
        PixelFormat::Bpp24 => {
            out.extend(pixels.iter().copied());
        }
    }
    Ok(out)
}

/// Words the FSM consumes/produces for `n_pixels` at `format`.
pub fn words_for_pixels(n_pixels: usize, format: PixelFormat) -> usize {
    n_pixels.div_ceil(format.pixels_per_word())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn unpack_8bpp_le_order() {
        let px = unpack_words(&[0xDDCCBBAA], PixelFormat::Bpp8, 4).unwrap();
        assert_eq!(px, vec![0xAA, 0xBB, 0xCC, 0xDD]);
    }

    #[test]
    fn unpack_16bpp() {
        let px = unpack_words(&[0xBEEF_F00D], PixelFormat::Bpp16, 2).unwrap();
        assert_eq!(px, vec![0xF00D, 0xBEEF]);
    }

    #[test]
    fn unpack_24bpp_masks_top_byte() {
        let px = unpack_words(&[0xFF123456], PixelFormat::Bpp24, 1).unwrap();
        assert_eq!(px, vec![0x123456]);
    }

    #[test]
    fn unpack_partial_final_word() {
        let px = unpack_words(&[0x04030201, 0x00000005], PixelFormat::Bpp8, 5).unwrap();
        assert_eq!(px, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn unpack_rejects_short_input() {
        assert!(unpack_words(&[0], PixelFormat::Bpp8, 5).is_err());
    }

    #[test]
    fn pack_rejects_oversized_pixel() {
        assert!(pack_words(&[0x1FF], PixelFormat::Bpp8).is_err());
    }

    #[test]
    fn words_for_pixels_rounding() {
        assert_eq!(words_for_pixels(5, PixelFormat::Bpp8), 2);
        assert_eq!(words_for_pixels(4, PixelFormat::Bpp8), 1);
        assert_eq!(words_for_pixels(3, PixelFormat::Bpp16), 2);
        assert_eq!(words_for_pixels(3, PixelFormat::Bpp24), 3);
    }

    #[test]
    fn prop_pack_unpack_roundtrip_all_formats() {
        check("pack/unpack roundtrip", 96, |g: &mut Gen| {
            let format = *g.choose(&[
                PixelFormat::Bpp8,
                PixelFormat::Bpp16,
                PixelFormat::Bpp24,
            ]);
            let n = g.int_in(1, 300);
            let max = format.max_value();
            let pixels: Vec<u32> =
                (0..n).map(|_| g.u32() & max).collect();
            let words = pack_words(&pixels, format).unwrap();
            if words.len() != words_for_pixels(n, format) {
                return false;
            }
            unpack_words(&words, format, n).unwrap() == pixels
        });
    }
}
