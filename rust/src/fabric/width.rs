//! Width-conversion FSMs (paper Fig. 2): the CIF FSM converts 32-bit bus
//! words into 8/16/24-bit wire pixels; the LCD FSM performs the inverse.
//!
//! Packing convention (little-endian within the word, matching the VHDL):
//! * 8 bpp : word = px0 | px1<<8 | px2<<16 | px3<<24  (4 px/word)
//! * 16 bpp: word = px0 | px1<<16                      (2 px/word)
//! * 24 bpp: word = px0 (bits 23:0; 31:24 unused)      (1 px/word)

use crate::error::{Error, Result};
use crate::util::image::PixelFormat;

/// 32-bit words -> pixels (CIF direction).
///
/// Bulk path: full words unpack through fixed-lane `chunks_exact` loops
/// (no per-pixel length test, auto-vectorizable); only the final partial
/// word runs the per-lane tail. Pinned to [`unpack_words_ref`] by
/// `tests/kernel_equivalence.rs`.
pub fn unpack_words(words: &[u32], format: PixelFormat, n_pixels: usize) -> Result<Vec<u32>> {
    let ppw = format.pixels_per_word();
    let needed = n_pixels.div_ceil(ppw);
    if words.len() < needed {
        return Err(Error::Geometry(format!(
            "{n_pixels} px at {}bpp need {needed} words, got {}",
            format.bits(),
            words.len()
        )));
    }
    let mut out = vec![0u32; n_pixels];
    match format {
        PixelFormat::Bpp8 => {
            let full = n_pixels / 4;
            for (px, &w) in out.chunks_exact_mut(4).zip(words) {
                px[0] = w & 0xFF;
                px[1] = (w >> 8) & 0xFF;
                px[2] = (w >> 16) & 0xFF;
                px[3] = w >> 24;
            }
            for (i, px) in out[full * 4..].iter_mut().enumerate() {
                *px = (words[full] >> (8 * i)) & 0xFF;
            }
        }
        PixelFormat::Bpp16 => {
            let full = n_pixels / 2;
            for (px, &w) in out.chunks_exact_mut(2).zip(words) {
                px[0] = w & 0xFFFF;
                px[1] = w >> 16;
            }
            if n_pixels % 2 == 1 {
                out[n_pixels - 1] = words[full] & 0xFFFF;
            }
        }
        PixelFormat::Bpp24 => {
            for (px, &w) in out.iter_mut().zip(words) {
                *px = w & 0x00FF_FFFF;
            }
        }
    }
    Ok(out)
}

/// Reference twin of [`unpack_words`]: the FSM-faithful lane-by-lane
/// loop (one pixel per FSM step, exactly as the HDL shifts them out).
pub fn unpack_words_ref(words: &[u32], format: PixelFormat, n_pixels: usize) -> Result<Vec<u32>> {
    let ppw = format.pixels_per_word();
    let needed = n_pixels.div_ceil(ppw);
    if words.len() < needed {
        return Err(Error::Geometry(format!(
            "{n_pixels} px at {}bpp need {needed} words, got {}",
            format.bits(),
            words.len()
        )));
    }
    let mut out = Vec::with_capacity(n_pixels);
    if n_pixels == 0 {
        return Ok(out);
    }
    'outer: for &w in words {
        match format {
            PixelFormat::Bpp8 => {
                for i in 0..4 {
                    out.push((w >> (8 * i)) & 0xFF);
                    if out.len() == n_pixels {
                        break 'outer;
                    }
                }
            }
            PixelFormat::Bpp16 => {
                for i in 0..2 {
                    out.push((w >> (16 * i)) & 0xFFFF);
                    if out.len() == n_pixels {
                        break 'outer;
                    }
                }
            }
            PixelFormat::Bpp24 => {
                out.push(w & 0x00FF_FFFF);
                if out.len() == n_pixels {
                    break 'outer;
                }
            }
        }
    }
    Ok(out)
}

/// Pixels -> 32-bit words (LCD direction). The final partial word is
/// zero-padded in its unused lanes, as the HDL register would hold zeros.
///
/// Bulk path: full words assemble through fixed-lane `chunks_exact`
/// loops; the partial tail (if any) is built separately. Pinned to
/// [`pack_words_ref`] by `tests/kernel_equivalence.rs`.
pub fn pack_words(pixels: &[u32], format: PixelFormat) -> Result<Vec<u32>> {
    let max = format.max_value();
    if let Some(&bad) = pixels.iter().find(|&&p| p > max) {
        return Err(Error::Geometry(format!(
            "pixel {bad:#x} exceeds {}bpp",
            format.bits()
        )));
    }
    let ppw = format.pixels_per_word();
    let mut out = vec![0u32; pixels.len().div_ceil(ppw)];
    match format {
        PixelFormat::Bpp8 => {
            for (w, px) in out.iter_mut().zip(pixels.chunks_exact(4)) {
                *w = px[0] | (px[1] << 8) | (px[2] << 16) | (px[3] << 24);
            }
            let full = pixels.len() / 4;
            if pixels.len() % 4 != 0 {
                let mut tail = 0u32;
                for (i, &p) in pixels[full * 4..].iter().enumerate() {
                    tail |= p << (8 * i);
                }
                out[full] = tail;
            }
        }
        PixelFormat::Bpp16 => {
            for (w, px) in out.iter_mut().zip(pixels.chunks_exact(2)) {
                *w = px[0] | (px[1] << 16);
            }
            if pixels.len() % 2 == 1 {
                out[pixels.len() / 2] = pixels[pixels.len() - 1];
            }
        }
        PixelFormat::Bpp24 => {
            out.copy_from_slice(pixels);
        }
    }
    Ok(out)
}

/// Reference twin of [`pack_words`]: the FSM-faithful per-lane loop.
pub fn pack_words_ref(pixels: &[u32], format: PixelFormat) -> Result<Vec<u32>> {
    let max = format.max_value();
    if let Some(&bad) = pixels.iter().find(|&&p| p > max) {
        return Err(Error::Geometry(format!(
            "pixel {bad:#x} exceeds {}bpp",
            format.bits()
        )));
    }
    let ppw = format.pixels_per_word();
    let mut out = Vec::with_capacity(pixels.len().div_ceil(ppw));
    match format {
        PixelFormat::Bpp8 => {
            for chunk in pixels.chunks(4) {
                let mut w = 0u32;
                for (i, &p) in chunk.iter().enumerate() {
                    w |= p << (8 * i);
                }
                out.push(w);
            }
        }
        PixelFormat::Bpp16 => {
            for chunk in pixels.chunks(2) {
                let mut w = 0u32;
                for (i, &p) in chunk.iter().enumerate() {
                    w |= p << (16 * i);
                }
                out.push(w);
            }
        }
        PixelFormat::Bpp24 => {
            out.extend(pixels.iter().copied());
        }
    }
    Ok(out)
}

/// Words the FSM consumes/produces for `n_pixels` at `format`.
pub fn words_for_pixels(n_pixels: usize, format: PixelFormat) -> usize {
    n_pixels.div_ceil(format.pixels_per_word())
}

/// XOR `src` into `acc` lane-wise — the parity accumulator of the FEC
/// framing (ISSUE 9): the FPGA XORs payload lines into the parity-line
/// registers as they stream through the width FSM, so erasure recovery
/// is a pure re-XOR of the surviving lines. Pixel values stay within
/// their format's bit budget (XOR of in-range lanes is in range).
pub fn xor_line(acc: &mut [u32], src: &[u32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, &s) in acc.iter_mut().zip(src) {
        *a ^= s;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn unpack_8bpp_le_order() {
        let px = unpack_words(&[0xDDCCBBAA], PixelFormat::Bpp8, 4).unwrap();
        assert_eq!(px, vec![0xAA, 0xBB, 0xCC, 0xDD]);
    }

    #[test]
    fn unpack_16bpp() {
        let px = unpack_words(&[0xBEEF_F00D], PixelFormat::Bpp16, 2).unwrap();
        assert_eq!(px, vec![0xF00D, 0xBEEF]);
    }

    #[test]
    fn unpack_24bpp_masks_top_byte() {
        let px = unpack_words(&[0xFF123456], PixelFormat::Bpp24, 1).unwrap();
        assert_eq!(px, vec![0x123456]);
    }

    #[test]
    fn unpack_partial_final_word() {
        let px = unpack_words(&[0x04030201, 0x00000005], PixelFormat::Bpp8, 5).unwrap();
        assert_eq!(px, vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn unpack_rejects_short_input() {
        assert!(unpack_words(&[0], PixelFormat::Bpp8, 5).is_err());
    }

    #[test]
    fn pack_rejects_oversized_pixel() {
        assert!(pack_words(&[0x1FF], PixelFormat::Bpp8).is_err());
    }

    #[test]
    fn words_for_pixels_rounding() {
        assert_eq!(words_for_pixels(5, PixelFormat::Bpp8), 2);
        assert_eq!(words_for_pixels(4, PixelFormat::Bpp8), 1);
        assert_eq!(words_for_pixels(3, PixelFormat::Bpp16), 2);
        assert_eq!(words_for_pixels(3, PixelFormat::Bpp24), 3);
    }

    #[test]
    fn xor_line_is_involutive_and_in_range() {
        let a0: Vec<u32> = vec![0x12, 0xFF, 0x00, 0x80];
        let b: Vec<u32> = vec![0xFF, 0x0F, 0xAA, 0x01];
        let mut a = a0.clone();
        xor_line(&mut a, &b);
        assert_ne!(a, a0);
        assert!(a.iter().all(|&v| v <= 0xFF), "8bpp lanes stay in range");
        xor_line(&mut a, &b);
        assert_eq!(a, a0);
    }

    #[test]
    fn prop_pack_unpack_roundtrip_all_formats() {
        check("pack/unpack roundtrip", 96, |g: &mut Gen| {
            let format = *g.choose(&[
                PixelFormat::Bpp8,
                PixelFormat::Bpp16,
                PixelFormat::Bpp24,
            ]);
            let n = g.int_in(1, 300);
            let max = format.max_value();
            let pixels: Vec<u32> =
                (0..n).map(|_| g.u32() & max).collect();
            let words = pack_words(&pixels, format).unwrap();
            if words.len() != words_for_pixels(n, format) {
                return false;
            }
            unpack_words(&words, format, n).unwrap() == pixels
        });
    }
}
