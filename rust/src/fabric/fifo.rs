//! Pixel/word FIFOs: the buffering elements of the paper's CIF/LCD design.
//!
//! [`SyncFifo`] is a single-clock FIFO with occupancy tracking (the image
//! buffers and pixel FIFOs of Fig. 2). [`CdcFifo`] adds the paper's
//! clock-domain-crossing behaviour ("our FPGA design uses FIFOs capable of
//! clock domain crossing, allowing different clocks to be employed for the
//! CIF and LCD modules"): items written in the producer domain become
//! visible to the consumer only after a 2-flop gray-pointer synchronizer
//! delay in the consumer's clock.

use crate::error::{Error, Result};
use crate::fabric::clock::{ClockDomain, SimTime};
use std::collections::VecDeque;

/// Single-clock FIFO with high-water-mark statistics.
#[derive(Clone, Debug)]
pub struct SyncFifo<T> {
    name: &'static str,
    capacity: usize,
    items: VecDeque<T>,
    /// Highest occupancy ever observed (for buffer-sizing reports).
    pub high_water: usize,
    /// Counts of rejected operations (flow-control pressure metrics).
    pub overflow_attempts: u64,
    pub underflow_attempts: u64,
}

impl<T> SyncFifo<T> {
    pub fn new(name: &'static str, capacity: usize) -> SyncFifo<T> {
        assert!(capacity > 0, "fifo {name} needs capacity");
        SyncFifo {
            name,
            capacity,
            items: VecDeque::with_capacity(capacity),
            high_water: 0,
            overflow_attempts: 0,
            underflow_attempts: 0,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() == self.capacity
    }

    pub fn free(&self) -> usize {
        self.capacity - self.items.len()
    }

    /// Push; error on overflow (an unflow-controlled HDL bug).
    pub fn push(&mut self, item: T) -> Result<()> {
        if self.is_full() {
            self.overflow_attempts += 1;
            return Err(Error::Fifo {
                name: self.name,
                kind: "overflow",
                capacity: self.capacity,
            });
        }
        self.items.push_back(item);
        self.high_water = self.high_water.max(self.items.len());
        Ok(())
    }

    /// Push, returning false when full (flow-controlled producer).
    pub fn try_push(&mut self, item: T) -> bool {
        if self.is_full() {
            self.overflow_attempts += 1;
            return false;
        }
        self.items.push_back(item);
        self.high_water = self.high_water.max(self.items.len());
        true
    }

    pub fn pop(&mut self) -> Result<T> {
        match self.items.pop_front() {
            Some(v) => Ok(v),
            None => {
                self.underflow_attempts += 1;
                Err(Error::Fifo {
                    name: self.name,
                    kind: "underflow",
                    capacity: self.capacity,
                })
            }
        }
    }

    pub fn try_pop(&mut self) -> Option<T> {
        let v = self.items.pop_front();
        if v.is_none() {
            self.underflow_attempts += 1;
        }
        v
    }

    pub fn clear(&mut self) {
        self.items.clear();
    }
}

/// Dual-clock FIFO: write side in `wr_clk`, read side in `rd_clk`.
///
/// Transaction-level CDC model: an item pushed at write-domain time `t_w`
/// becomes readable at the first read-domain edge at or after
/// `t_w + 2 / f_rd` (two synchronizer flops). Occupancy (for *full*
/// detection) is conservative on the write side symmetrically.
#[derive(Clone, Debug)]
pub struct CdcFifo<T> {
    inner: SyncFifo<(SimTime, T)>,
    pub wr_clk: ClockDomain,
    pub rd_clk: ClockDomain,
}

impl<T> CdcFifo<T> {
    pub fn new(
        name: &'static str,
        capacity: usize,
        wr_clk: ClockDomain,
        rd_clk: ClockDomain,
    ) -> CdcFifo<T> {
        CdcFifo {
            inner: SyncFifo::new(name, capacity),
            wr_clk,
            rd_clk,
        }
    }

    pub fn len(&self) -> usize {
        self.inner.len()
    }

    pub fn is_empty(&self) -> bool {
        self.inner.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.inner.is_full()
    }

    pub fn high_water(&self) -> usize {
        self.inner.high_water
    }

    /// Push at write-domain time `now`.
    pub fn push(&mut self, now: SimTime, item: T) -> Result<()> {
        let visible = self
            .rd_clk
            .next_edge(now + self.rd_clk.cycles(2));
        self.inner.push((visible, item))
    }

    /// Pop at read-domain time `now`; `None` if empty *or* the head item
    /// has not yet crossed the synchronizer.
    pub fn pop(&mut self, now: SimTime) -> Option<T> {
        match self.inner.items.front() {
            Some((visible, _)) if *visible <= now => {
                self.inner.items.pop_front().map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Earliest read-domain time at which the head item becomes readable.
    pub fn head_ready_at(&self) -> Option<SimTime> {
        self.inner.items.front().map(|(t, _)| *t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Gen};

    #[test]
    fn sync_fifo_order_preserved() {
        let mut f = SyncFifo::new("t", 4);
        for i in 0..4 {
            f.push(i).unwrap();
        }
        assert!(f.is_full());
        for i in 0..4 {
            assert_eq!(f.pop().unwrap(), i);
        }
        assert!(f.is_empty());
    }

    #[test]
    fn sync_fifo_overflow_and_underflow() {
        let mut f = SyncFifo::new("t", 1);
        f.push(1u32).unwrap();
        assert!(f.push(2).is_err());
        assert_eq!(f.overflow_attempts, 1);
        f.pop().unwrap();
        assert!(f.pop().is_err());
        assert_eq!(f.underflow_attempts, 1);
    }

    #[test]
    fn high_water_tracks_peak() {
        let mut f = SyncFifo::new("t", 8);
        for i in 0..5 {
            f.push(i).unwrap();
        }
        for _ in 0..5 {
            f.pop().unwrap();
        }
        f.push(0).unwrap();
        assert_eq!(f.high_water, 5);
    }

    #[test]
    fn prop_fifo_is_order_preserving_queue() {
        check("fifo preserves order under random ops", 64, |g: &mut Gen| {
            let mut model: std::collections::VecDeque<u32> = Default::default();
            let mut fifo = SyncFifo::new("prop", 16);
            for _ in 0..g.int_in(1, 200) {
                if g.bool() {
                    let v = g.u32();
                    let ok = fifo.try_push(v);
                    if model.len() < 16 {
                        if !ok {
                            return false;
                        }
                        model.push_back(v);
                    } else if ok {
                        return false;
                    }
                } else {
                    let got = fifo.try_pop();
                    let want = model.pop_front();
                    if got != want {
                        return false;
                    }
                }
                if fifo.len() != model.len() {
                    return false;
                }
            }
            true
        });
    }

    #[test]
    fn cdc_item_invisible_until_synchronized() {
        let wr = ClockDomain::new(50.0e6); // 20 ns
        let rd = ClockDomain::new(25.0e6); // 40 ns
        let mut f = CdcFifo::new("cdc", 8, wr, rd);
        let t0 = SimTime(0);
        f.push(t0, 99u32).unwrap();
        // 2 read cycles = 80 ns: not readable before.
        assert_eq!(f.pop(SimTime(79_999)), None);
        assert_eq!(f.pop(SimTime(80_000)), Some(99));
    }

    #[test]
    fn cdc_respects_read_clock_edges() {
        let wr = ClockDomain::new(100.0e6);
        let rd = ClockDomain::new(30.0e6); // period 33333 ps
        let mut f = CdcFifo::new("cdc", 8, wr, rd);
        f.push(SimTime(10_000), 1u8).unwrap();
        let ready = f.head_ready_at().unwrap();
        // Ready time must lie on a read-domain edge.
        assert_eq!(ready.0 % rd.period().0, 0);
        assert!(ready >= SimTime(10_000) + rd.cycles(2));
    }

    #[test]
    fn cdc_keeps_fifo_semantics_per_domain() {
        let clk = ClockDomain::new(50.0e6);
        let mut f = CdcFifo::new("cdc", 2, clk, clk);
        f.push(SimTime(0), 1u32).unwrap();
        f.push(SimTime(0), 2u32).unwrap();
        assert!(f.is_full());
        assert!(f.push(SimTime(0), 3u32).is_err());
        let late = SimTime(1_000_000);
        assert_eq!(f.pop(late), Some(1));
        assert_eq!(f.pop(late), Some(2));
        assert_eq!(f.pop(late), None);
    }
}
