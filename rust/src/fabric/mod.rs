//! FPGA fabric primitives: the simulated HDL building blocks of the
//! paper's CIF/LCD interface design (Fig. 2).
//!
//! Everything here is *transaction-level with cycle accounting*: data moves
//! through the same components the VHDL instantiates (FIFOs, width FSMs,
//! CRC, register files) and every component reports how many cycles of its
//! clock domain an operation consumed; `clock` converts cycles to
//! simulated time.

pub mod bus;
pub mod clock;
pub mod crc16;
pub mod fifo;
pub mod regs;
pub mod width;

pub use clock::{ClockDomain, SimTime};
pub use crc16::Crc16Xmodem;
pub use fifo::{CdcFifo, SyncFifo};
